//! Shared intra-op worker pool.
//!
//! One process-wide, lazily-started pool (std-only: `std::thread` +
//! `Mutex`/`Condvar`) executes the data-parallel regions of the three hot
//! loops — fused elementwise kernels ([`crate::vm::fused`]), the blocked
//! matmul ([`crate::tensor::matmul`]), and the serve batcher's sharded
//! vmapped dispatch ([`crate::serve`]). The IR is purely functional, so a
//! kernel's index space has no cross-iteration dependences and can be split
//! freely; the pool's job is to do that split *deterministically*.
//!
//! # Determinism contract
//!
//! Parallel execution is bit-identical to sequential execution, by
//! construction:
//!
//! * **Chunk boundaries derive only from shape.** Every split uses fixed
//!   chunk sizes (consts below) applied to the output's element count —
//!   never the live thread count — so the partition is a pure function of
//!   the tensor shapes.
//! * **Disjoint writes.** Each task owns a contiguous `&mut` slice of one
//!   pre-allocated output buffer; there is no shared accumulator.
//! * **Per-chunk sequential reduction.** Reductions (the matmul `k` loop)
//!   run entirely inside one task in the same order as the sequential
//!   kernel; chunks never split a reduction, so there is no combine step
//!   whose association could vary.
//! * **Small-size bypass.** Index spaces below the thresholds run inline on
//!   the calling thread — microscopic tensors never pay handoff latency,
//!   and (trivially) keep sequential results.
//!
//! # Sizing
//!
//! The pool holds `intra_op_threads() - 1` workers (the caller is the
//! remaining lane). The initial size comes from the `MYIA_THREADS`
//! environment variable when set (clamped to `[1, MAX_THREADS]`), else
//! `std::thread::available_parallelism()`. Benches and tests resize at
//! runtime with [`set_intra_op_threads`]; shrinking parks the surplus
//! workers rather than joining them.
//!
//! # Scheduling
//!
//! [`Pool::scope_run`] enqueues a batch of borrowing closures and then the
//! *caller helps*: it drains the shared queue until empty and finally waits
//! on a latch for its own tasks. Every queued task is executed by someone
//! (a worker or the helping caller), so the scheme cannot deadlock even
//! with zero workers. Nested data-parallel regions (a fused kernel inside a
//! sharded serve batch, say) run inline — a thread-local flag marks pool
//! tasks, and [`parallel_enabled`] returns false inside one — which bounds
//! the pool's working set and avoids oversubscription.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::budget::{CancelToken, Trap};

/// Elements per fused-loop chunk (and the unit the matmul/serve splits are
/// scaled against). Boundaries are `k * FUSED_CHUNK_ELEMS`, a pure function
/// of the output element count.
pub const FUSED_CHUNK_ELEMS: usize = 16 * 1024;

/// Fused loops with fewer output elements than this run inline (a single
/// chunk would gain nothing; two tiny chunks would pay handoff latency).
pub const FUSED_PAR_MIN_ELEMS: usize = 2 * FUSED_CHUNK_ELEMS;

/// Output rows per matmul task.
pub const MATMUL_ROW_CHUNK: usize = 8;

/// `m * k * n` below which a matmul runs inline. Also the per-task floor
/// `batch_matmul` uses when grouping examples.
pub const MATMUL_PAR_MIN_FLOPS: usize = 128 * 1024;

/// Examples per serve-batcher shard.
pub const SERVE_SHARD_EXAMPLES: usize = 8;

/// Hard cap on pool size; `MYIA_THREADS` and [`set_intra_op_threads`] are
/// clamped to it.
pub const MAX_THREADS: usize = 64;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Completion latch for one `scope_run` batch.
struct Latch {
    state: Mutex<(usize, usize)>, // (remaining, panicked)
    all_done: Condvar,
}

impl Latch {
    fn new(remaining: usize) -> Arc<Latch> {
        Arc::new(Latch { state: Mutex::new((remaining, 0)), all_done: Condvar::new() })
    }

    fn done(&self, panicked: bool) {
        let mut st = self.state.lock().expect("pool latch poisoned");
        st.0 -= 1;
        if panicked {
            st.1 += 1;
        }
        if st.0 == 0 {
            self.all_done.notify_all();
        }
    }

    /// Block until every task has settled; returns how many panicked.
    fn wait(&self) -> usize {
        let mut st = self.state.lock().expect("pool latch poisoned");
        while st.0 > 0 {
            st = self.all_done.wait(st).expect("pool latch poisoned");
        }
        st.1
    }
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    work: Condvar,
    /// Current lane limit (callers count as one lane); workers with index
    /// `>= limit - 1` park until the limit grows again.
    limit: AtomicUsize,
}

/// The process-wide worker pool. Obtain it with [`pool`].
pub struct Pool {
    shared: Arc<Shared>,
    /// Workers spawned so far (monotone; shrinking parks, never joins).
    spawned: Mutex<usize>,
}

thread_local! {
    /// True while this thread is executing a pool task; nested regions see
    /// it via [`parallel_enabled`] and run inline.
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Parse a `MYIA_THREADS`-style override against a fallback lane count.
/// Zero, negatives, and garbage fall back; everything clamps to
/// [`MAX_THREADS`].
fn parse_threads(var: Option<&str>, fallback: usize) -> usize {
    match var.and_then(|s| s.trim().parse::<usize>().ok()) {
        Some(n) if n >= 1 => n.min(MAX_THREADS),
        _ => fallback.clamp(1, MAX_THREADS),
    }
}

fn initial_threads() -> usize {
    let fallback = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let var = std::env::var("MYIA_THREADS").ok();
    parse_threads(var.as_deref(), fallback)
}

/// The shared pool (created, but with no threads spawned, on first use;
/// workers start lazily on the first parallel region).
pub fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        shared: Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            limit: AtomicUsize::new(initial_threads()),
        }),
        spawned: Mutex::new(0),
    })
}

/// Current lane limit (1 = sequential).
pub fn intra_op_threads() -> usize {
    pool().shared.limit.load(Ordering::Relaxed)
}

/// Resize the pool at runtime (benches and the determinism suite sweep 1,
/// 2, 8 lanes). Results are unaffected — chunking never consults this.
pub fn set_intra_op_threads(n: usize) {
    let p = pool();
    let n = n.clamp(1, MAX_THREADS);
    p.shared.limit.store(n, Ordering::Relaxed);
    p.ensure_workers(n);
    // Wake parked workers whose index just became active.
    p.shared.work.notify_all();
}

/// True when a data-parallel region would actually fan out: more than one
/// lane, and not already inside a pool task (nested regions run inline).
pub fn parallel_enabled() -> bool {
    intra_op_threads() > 1 && !IN_POOL_TASK.with(|f| f.get())
}

/// Pop one queued job. A helper (rather than an inline `while let`) so the
/// queue guard is provably dropped before the job runs.
fn pop_job(shared: &Shared) -> Option<Job> {
    shared.queue.lock().expect("pool queue poisoned").pop_front()
}

fn run_job(job: Job) {
    IN_POOL_TASK.with(|f| {
        let prev = f.get();
        f.set(true);
        // Jobs never unwind: `scope_run` wraps each task in `catch_unwind`.
        job();
        f.set(prev);
    });
}

fn worker(shared: Arc<Shared>, index: usize) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("pool queue poisoned");
            loop {
                if index + 1 < shared.limit.load(Ordering::Relaxed) {
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                }
                q = shared.work.wait(q).expect("pool queue poisoned");
            }
        };
        run_job(job);
    }
}

impl Pool {
    fn ensure_workers(&self, limit: usize) {
        let want = limit.saturating_sub(1);
        let mut spawned = self.spawned.lock().expect("pool spawn registry poisoned");
        while *spawned < want {
            let index = *spawned;
            let shared = Arc::clone(&self.shared);
            std::thread::Builder::new()
                .name(format!("myia-intra-op-{index}"))
                .spawn(move || worker(shared, index))
                .expect("spawn intra-op worker");
            *spawned += 1;
        }
    }

    /// Run `tasks` to completion across the pool. The calling thread helps
    /// drain the queue, so completion never depends on workers existing.
    /// Panics (after every task has settled — no slice is left mid-write)
    /// if any task panicked.
    pub fn scope_run<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        if tasks.len() == 1 || !parallel_enabled() {
            for t in tasks {
                t();
            }
            return;
        }
        self.ensure_workers(self.shared.limit.load(Ordering::Relaxed));
        let latch = Latch::new(tasks.len());
        {
            let mut q = self.shared.queue.lock().expect("pool queue poisoned");
            for t in tasks {
                // SAFETY: every task settles before `scope_run` returns —
                // the latch below counts all of them down, and we wait on
                // it — so borrows captured by the task cannot outlive the
                // caller's frame. The erased lifetime is never observable.
                let t: Box<dyn FnOnce() + Send + 'static> = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(t)
                };
                let latch = Arc::clone(&latch);
                q.push_back(Box::new(move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        crate::faultinject::panic_or_stall_at(crate::faultinject::Site::PoolTask);
                        t()
                    }));
                    latch.done(r.is_err());
                }));
            }
        }
        self.shared.work.notify_all();
        // Help: run queued jobs (ours or a concurrent scope's — either is
        // progress) until the queue is empty.
        while let Some(job) = pop_job(&self.shared) {
            run_job(job);
        }
        let panicked = latch.wait();
        if panicked > 0 {
            panic!("{panicked} intra-op pool task(s) panicked");
        }
    }
}

/// Split `data` into fixed `chunk`-element pieces (boundaries depend only
/// on `data.len()` and `chunk` — never on thread count) and run
/// `f(piece, base_offset)` for each across the pool. Runs inline when
/// there is a single piece or parallelism is off.
pub fn for_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(&mut [T], usize) + Sync,
{
    assert!(chunk > 0, "pool chunk size must be positive");
    if data.is_empty() {
        return;
    }
    if data.len() <= chunk || !parallel_enabled() {
        f(data, 0);
        return;
    }
    let fr = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = data
        .chunks_mut(chunk)
        .enumerate()
        .map(|(i, piece)| Box::new(move || fr(piece, i * chunk)) as Box<dyn FnOnce() + Send + '_>)
        .collect();
    pool().scope_run(tasks);
}

/// [`for_chunks_mut`] with cooperative cancellation: before running and
/// between chunks each lane consults `token`, and chunks whose token has
/// already fired are skipped (their slice is left untouched — the caller
/// discards the output on `Err`). Chunk boundaries are identical to the
/// uncancelled variant, so a run that completes without tripping the token
/// is bit-identical to [`for_chunks_mut`].
pub fn for_chunks_mut_cancellable<T, F>(
    data: &mut [T],
    chunk: usize,
    token: Option<&CancelToken>,
    f: F,
) -> Result<(), Trap>
where
    T: Send,
    F: Fn(&mut [T], usize) + Sync,
{
    let Some(tok) = token else {
        for_chunks_mut(data, chunk, f);
        return Ok(());
    };
    tok.check()?;
    for_chunks_mut(data, chunk, |piece, base| {
        if !tok.should_stop() {
            f(piece, base);
        }
    });
    tok.check()
}

/// Pool-size mutations are process-global; in-crate tests that resize the
/// pool hold this to serialize against each other.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    #[test]
    fn parse_threads_override_and_fallback() {
        assert_eq!(parse_threads(Some("4"), 8), 4);
        assert_eq!(parse_threads(Some(" 2 "), 8), 2);
        assert_eq!(parse_threads(Some("0"), 8), 8); // zero falls back
        assert_eq!(parse_threads(Some("nope"), 8), 8);
        assert_eq!(parse_threads(None, 8), 8);
        assert_eq!(parse_threads(Some("9999"), 8), MAX_THREADS);
        assert_eq!(parse_threads(None, 0), 1); // fallback itself clamps
    }

    #[test]
    fn chunked_fill_covers_every_index_once() {
        let _g = lock();
        let prev = intra_op_threads();
        for lanes in [1, 2, 8] {
            set_intra_op_threads(lanes);
            let mut data = vec![0u32; 10_000];
            for_chunks_mut(&mut data, 1024, |piece, base| {
                for (j, cell) in piece.iter_mut().enumerate() {
                    *cell += (base + j) as u32;
                }
            });
            for (k, v) in data.iter().enumerate() {
                assert_eq!(*v, k as u32, "index {k} at {lanes} lanes");
            }
        }
        set_intra_op_threads(prev);
    }

    #[test]
    fn scope_run_runs_every_task_and_propagates_panics() {
        let _g = lock();
        let prev = intra_op_threads();
        set_intra_op_threads(4);
        let hits = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..32)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool().scope_run(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 32);

        let survivors = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|i| {
                let survivors = &survivors;
                Box::new(move || {
                    if i == 3 {
                        panic!("task boom");
                    }
                    survivors.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool().scope_run(tasks);
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // All non-panicking tasks still settled before the propagation.
        assert_eq!(survivors.load(Ordering::Relaxed), 7);
        set_intra_op_threads(prev);
    }

    #[test]
    fn cancellable_chunks_match_plain_and_trip_on_cancel() {
        let _g = lock();
        let prev = intra_op_threads();
        set_intra_op_threads(4);
        // Without a token (or with a live one) results match for_chunks_mut.
        let mut a = vec![0u32; 5_000];
        for_chunks_mut_cancellable(&mut a, 512, None, |piece, base| {
            for (j, cell) in piece.iter_mut().enumerate() {
                *cell = (base + j) as u32;
            }
        })
        .unwrap();
        let token = CancelToken::new();
        let mut b = vec![0u32; 5_000];
        for_chunks_mut_cancellable(&mut b, 512, Some(&token), |piece, base| {
            for (j, cell) in piece.iter_mut().enumerate() {
                *cell = (base + j) as u32;
            }
        })
        .unwrap();
        assert_eq!(a, b);
        // A pre-cancelled token refuses before any chunk runs.
        token.cancel();
        let mut c = vec![0u32; 5_000];
        let e = for_chunks_mut_cancellable(&mut c, 512, Some(&token), |_, _| {
            panic!("must not run after cancellation");
        })
        .unwrap_err();
        assert!(matches!(e, Trap::Cancelled));
        assert!(c.iter().all(|&v| v == 0));
        set_intra_op_threads(prev);
    }

    #[test]
    fn nested_regions_run_inline() {
        let _g = lock();
        let prev = intra_op_threads();
        set_intra_op_threads(4);
        let mut outer = vec![0u8; 4 * FUSED_CHUNK_ELEMS];
        for_chunks_mut(&mut outer, FUSED_CHUNK_ELEMS, |piece, _| {
            assert!(!parallel_enabled(), "nested region must be inline");
            let mut inner = vec![0u8; 8];
            for_chunks_mut(&mut inner, 2, |p, _| {
                for c in p.iter_mut() {
                    *c = 1;
                }
            });
            piece[0] = inner.iter().sum();
        });
        set_intra_op_threads(prev);
    }
}

//! The shape-specializing kernel tier (ROADMAP item 2).
//!
//! The IR is shape-erased, so generic dispatch pays a shape/dtype
//! simulation on every fused-kernel call (`vm/fused.rs`) and rebuilds
//! O(numel) broadcast index maps per call. This module caches that work
//! per *call site* and *argument shape*: the first call at a plan-eligible
//! `CallPrim` site with concrete shapes compiles a straight-line
//! [`KernelPlan`] — the resolved map space, dtype, per-leaf broadcast
//! access (index maps included) and the typed-vs-replay decision — into a
//! lock-free-read, shape-keyed cache hanging off the [`super::Vm`] (and
//! therefore off every `Executable` sharing it, across any number of
//! serving threads). Subsequent fixed-shape calls dispatch with zero
//! simulation.
//!
//! ## Concurrency
//!
//! Each site is a push-only linked list headed by an `AtomicPtr`. Readers
//! walk with `Acquire` loads and take no locks; writers publish a new head
//! with a `Release` compare-exchange. Two threads racing to compile the
//! same plan both succeed — the plans are identical (fully determined by
//! the shape/dtype key), one lands first and the other simply prepends a
//! duplicate that later lookups never reach past the first match. Nodes
//! are freed only when the cache is dropped.
//!
//! ## Keying and bypass
//!
//! Keys are shape + dtype for tensor arguments, kind-only for scalar
//! leaves of a fused kernel (their *values* change per call and never
//! affect the plan), and value-carrying for structural integers/bools
//! (batch flags, reduction axes, epilogue codes). There is deliberately
//! **no size-based bypass**: rank-0 outputs and batch-of-1 calls take the
//! plan path like any other shape. The only bypass is a value the key
//! cannot describe — symbolic zeros, tuples, closures — which dispatches
//! generically without touching the counters. A site accumulates at most
//! [`MAX_PLANS_PER_SITE`] plans; beyond that, new shapes execute
//! generically (counted as shape misses) instead of growing the list.
//!
//! ## Determinism
//!
//! A plan changes *where* shape work happens, never what is computed:
//! planned and generic execution are bit-identical at every pool size
//! (property-tested in `tests/test_specialize.rs`).
//!
//! ## Knobs
//!
//! `MYIA_SPECIALIZE=0` disables the tier at [`Vm`](super::Vm) construction;
//! [`PlanCache::set_enabled`] is the programmatic override (the serving
//! path may hold an `Executable` from the hot artifact cache whose `Vm` —
//! and plan cache — predates any env change).

use super::prims::eval_prim_inplace;
use super::value::Value;
use crate::ir::Prim;
use crate::tensor::DType;
use crate::vm::exec::ExecStats;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, Ordering};
use std::sync::Arc;

/// Sentinel for "this dispatch path has no plan site" (first-class prim
/// calls, tail-call resolution, cold constant folding).
pub const NO_SITE: u32 = u32::MAX;

/// Cap on distinct shape keys per site: a site cycling through more shapes
/// than this is shape-polymorphic for real, and caching would only grow an
/// unbounded list that every lookup walks.
pub const MAX_PLANS_PER_SITE: usize = 16;

/// Is `p` a specializable kernel site? (The bytecode compiler numbers one
/// plan slot per `CallPrim` of these.)
pub fn plan_eligible(p: Prim) -> bool {
    matches!(
        p,
        Prim::FusedMap
            | Prim::MatMul
            | Prim::BatchMatMul
            | Prim::MatMulEp
            | Prim::ReduceSum
            | Prim::SumTail
            | Prim::ReduceSumAxis
    )
}

/// One entry of a plan key.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgKey {
    /// A tensor argument: shape and dtype (the values never matter).
    Tensor(Box<[usize]>, DType),
    /// A scalar fused-kernel leaf — kind only, the value varies per call.
    ScalarF64,
    ScalarI64,
    ScalarBool,
    /// A structural integer whose *value* shapes the plan (reduction axis,
    /// epilogue code, integer batch flag).
    I64(i64),
    /// A structural bool whose value shapes the plan (batch flags).
    Bool(bool),
}

impl ArgKey {
    /// Key a fused-kernel leaf (scalar values keyed by kind only).
    fn of_leaf(v: &Value) -> Option<ArgKey> {
        Some(match v {
            Value::Tensor(t) => ArgKey::Tensor(t.shape().into(), t.dtype()),
            Value::F64(_) => ArgKey::ScalarF64,
            Value::I64(_) => ArgKey::ScalarI64,
            Value::Bool(_) => ArgKey::ScalarBool,
            _ => return None,
        })
    }

    /// Key a structural argument (flag/axis/code values are load-bearing).
    fn of_arg(v: &Value) -> Option<ArgKey> {
        Some(match v {
            Value::Tensor(t) => ArgKey::Tensor(t.shape().into(), t.dtype()),
            Value::F64(_) => ArgKey::ScalarF64,
            Value::I64(x) => ArgKey::I64(*x),
            Value::Bool(b) => ArgKey::Bool(*b),
            _ => return None,
        })
    }

    fn matches_leaf(&self, v: &Value) -> bool {
        match (self, v) {
            (ArgKey::Tensor(s, dt), Value::Tensor(t)) => {
                t.dtype() == *dt && t.shape() == &s[..]
            }
            (ArgKey::ScalarF64, Value::F64(_)) => true,
            (ArgKey::ScalarI64, Value::I64(_)) => true,
            (ArgKey::ScalarBool, Value::Bool(_)) => true,
            _ => false,
        }
    }

    fn matches_arg(&self, v: &Value) -> bool {
        match (self, v) {
            (ArgKey::Tensor(s, dt), Value::Tensor(t)) => {
                t.dtype() == *dt && t.shape() == &s[..]
            }
            (ArgKey::ScalarF64, Value::F64(_)) => true,
            (ArgKey::I64(x), Value::I64(y)) => x == y,
            (ArgKey::Bool(x), Value::Bool(y)) => x == y,
            _ => false,
        }
    }
}

/// Build a fused-leaf key (`None` when some leaf is unkeyable → bypass).
pub(crate) fn fused_leaf_keys(leaves: &[Value]) -> Option<Box<[ArgKey]>> {
    leaves.iter().map(ArgKey::of_leaf).collect()
}

/// Match a stored fused-leaf key against live leaves (no allocation).
pub(crate) fn fused_leaf_match(key: &[ArgKey], leaves: &[Value]) -> bool {
    key.len() == leaves.len() && key.iter().zip(leaves).all(|(k, v)| k.matches_leaf(v))
}

fn sized_keys(args: &[Value]) -> Option<Box<[ArgKey]>> {
    args.iter().map(ArgKey::of_arg).collect()
}

fn sized_match(key: &[ArgKey], args: &[Value]) -> bool {
    key.len() == args.len() && key.iter().zip(args).all(|(k, v)| k.matches_arg(v))
}

/// How the typed fused loop reads one leaf in the map space.
#[derive(Debug)]
pub enum LeafAccess {
    /// Scalar `Value` leaf: splat its (per-call) value.
    Scalar,
    /// Single-element tensor: splat element 0.
    TensorSplat,
    /// Shape equals the map space: direct indexing.
    Direct,
    /// Arbitrary broadcast: the cached index map, computed once per shape
    /// and lent to every call (`Rd::Mapped` borrows it).
    Mapped(Arc<Vec<usize>>),
}

/// The specialized form of one fused kernel for one leaf-shape key.
#[derive(Debug)]
pub struct TypedFused {
    /// The single float dtype every compute step lands on.
    pub dtype: DType,
    /// The map-space shape (pre-reduction output of the postfix program).
    pub map_shape: Box<[usize]>,
    /// Per-leaf access, aligned with the kernel's leaf order.
    pub access: Box<[LeafAccess]>,
}

/// Build per-leaf access for a typed fused plan, mirroring `Rd::new`'s
/// decision order exactly (single-element splat first, then direct, then
/// index-mapped) so planned and unplanned reads are the same reads.
pub(crate) fn build_access(leaves: &[Value], map_shape: &[usize]) -> Box<[LeafAccess]> {
    leaves
        .iter()
        .map(|v| match v {
            Value::Tensor(t) if t.numel() == 1 => LeafAccess::TensorSplat,
            Value::Tensor(t) if t.shape() == map_shape => LeafAccess::Direct,
            Value::Tensor(t) => LeafAccess::Mapped(Arc::new(
                crate::tensor::ops::broadcast_index_map(t.shape(), map_shape),
            )),
            _ => LeafAccess::Scalar,
        })
        .collect()
}

/// The fused-kernel plan kinds.
#[derive(Debug)]
pub enum FusedPlan {
    /// `simulate` landed on one float dtype: run the typed loop with the
    /// cached geometry (zero simulation on hits).
    Typed(Arc<TypedFused>),
    /// `simulate` declined for these shapes/dtypes (integer or mixed
    /// intermediates): replay immediately, skipping the re-simulation.
    Replay,
}

/// A compiled per-shape plan for one call site.
#[derive(Debug)]
pub enum KernelPlan {
    /// A fused elementwise/reduction kernel.
    Fused(FusedPlan),
    /// A matmul-family or standalone-reduction site: the plan pins the
    /// resolved output geometry for this key. The kernels' own geometry
    /// derivation is O(rank), so the hit's value here is the pinned
    /// decision and the telemetry, not skipped work.
    Sized { out_shape: Box<[usize]>, dtype: DType },
    /// The keyed call produced a non-tensor result (scalar-typed site);
    /// dispatch stays generic but the site is tracked.
    Opaque,
}

struct PlanNode {
    key: Box<[ArgKey]>,
    plan: KernelPlan,
    next: *mut PlanNode,
}

/// One call site's plans: a push-only, lock-free-read linked list.
pub struct Site {
    head: AtomicPtr<PlanNode>,
}

// The raw next-pointers are only ever read behind Acquire loads of a
// Release-published head, and nodes are freed exclusively by `Drop`
// (`&mut`), so sharing sites across threads is sound.
unsafe impl Send for Site {}
unsafe impl Sync for Site {}

impl Site {
    fn new() -> Site {
        Site { head: AtomicPtr::new(std::ptr::null_mut()) }
    }

    /// Lock-free lookup: walk the list, return the first plan whose key
    /// matches. The borrow is tied to `&self`; nodes live until `Drop`.
    pub fn find(&self, matches: impl Fn(&[ArgKey]) -> bool) -> Option<&KernelPlan> {
        let mut p = self.head.load(Ordering::Acquire);
        while !p.is_null() {
            let node = unsafe { &*p };
            if matches(&node.key) {
                return Some(&node.plan);
            }
            p = node.next;
        }
        None
    }

    /// Did this site ever compile a plan? (Distinguishes a first compile
    /// from a shape miss in the telemetry.)
    pub fn has_plans(&self) -> bool {
        !self.head.load(Ordering::Acquire).is_null()
    }

    /// Publish a plan. Returns `false` (dropping the plan) when the site
    /// is already at [`MAX_PLANS_PER_SITE`].
    pub fn insert(&self, key: Box<[ArgKey]>, plan: KernelPlan) -> bool {
        let mut node = Box::new(PlanNode { key, plan, next: std::ptr::null_mut() });
        let mut head = self.head.load(Ordering::Acquire);
        loop {
            let mut n = 0usize;
            let mut p = head;
            while !p.is_null() {
                n += 1;
                p = unsafe { (*p).next };
            }
            if n >= MAX_PLANS_PER_SITE {
                return false;
            }
            node.next = head;
            let raw = Box::into_raw(node);
            match self.head.compare_exchange(head, raw, Ordering::Release, Ordering::Acquire) {
                Ok(_) => return true,
                Err(cur) => {
                    // Lost the race: take the box back and retry against
                    // the new head (the racer may have inserted our key —
                    // a duplicate entry is correct, so no re-check).
                    node = unsafe { Box::from_raw(raw) };
                    head = cur;
                }
            }
        }
    }
}

impl Drop for Site {
    fn drop(&mut self) {
        let mut p = *self.head.get_mut();
        while !p.is_null() {
            let node = unsafe { Box::from_raw(p) };
            p = node.next;
        }
    }
}

/// Cumulative plan-tier counters (never reset; the serve metrics snapshot
/// them directly, unlike the drained per-call `ExecStats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanStats {
    pub plans_compiled: u64,
    pub plan_hits: u64,
    pub plan_shape_misses: u64,
}

/// The per-`Vm` plan cache: one [`Site`] per plan-eligible `CallPrim`.
pub struct PlanCache {
    sites: Box<[Site]>,
    enabled: AtomicBool,
    plans_compiled: AtomicU64,
    plan_hits: AtomicU64,
    plan_shape_misses: AtomicU64,
}

impl PlanCache {
    /// Build a cache with `n_sites` slots; enabled unless
    /// `MYIA_SPECIALIZE=0` (or `false`/`off`) is set.
    pub fn new(n_sites: usize) -> PlanCache {
        let enabled = !matches!(
            std::env::var("MYIA_SPECIALIZE").ok().as_deref(),
            Some("0") | Some("false") | Some("off")
        );
        PlanCache {
            sites: (0..n_sites).map(|_| Site::new()).collect(),
            enabled: AtomicBool::new(enabled),
            plans_compiled: AtomicU64::new(0),
            plan_hits: AtomicU64::new(0),
            plan_shape_misses: AtomicU64::new(0),
        }
    }

    /// The site for a dispatch, or `None` when the tier is off, the path
    /// has no site ([`NO_SITE`]), or the index is foreign to this program.
    pub fn site(&self, site: u32) -> Option<&Site> {
        if site == NO_SITE || !self.enabled.load(Ordering::Relaxed) {
            return None;
        }
        self.sites.get(site as usize)
    }

    /// Force the tier on/off for this `Vm` (overrides the env decision).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub(crate) fn note_hit(&self) {
        self.plan_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_compiled(&self) {
        self.plans_compiled.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_shape_miss(&self) {
        self.plan_shape_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot the cumulative counters.
    pub fn stats(&self) -> PlanStats {
        PlanStats {
            plans_compiled: self.plans_compiled.load(Ordering::Relaxed),
            plan_hits: self.plan_hits.load(Ordering::Relaxed),
            plan_shape_misses: self.plan_shape_misses.load(Ordering::Relaxed),
        }
    }
}

/// Dispatch a non-fused specializable site (matmul family, standalone
/// reductions) through the plan tier: key the call, count hit/compile/
/// shape-miss, pin the resolved output geometry on first sight, and
/// execute through the ordinary kernels either way.
pub(crate) fn dispatch_sized(
    p: Prim,
    args: &mut [Value],
    cache: &PlanCache,
    site: &Site,
    stats: &mut ExecStats,
) -> Result<Value> {
    if site.find(|k| sized_match(k, args)).is_some() {
        stats.plan_hits += 1;
        cache.note_hit();
        return eval_prim_inplace(p, args);
    }
    // Unkeyable arguments (symbolic zeros, tuples) bypass the tier.
    let Some(key) = sized_keys(args) else {
        return eval_prim_inplace(p, args);
    };
    let had_plans = site.has_plans();
    let v = eval_prim_inplace(p, args)?;
    let plan = match &v {
        Value::Tensor(t) => KernelPlan::Sized { out_shape: t.shape().into(), dtype: t.dtype() },
        _ => KernelPlan::Opaque,
    };
    if site.insert(key, plan) {
        stats.plans_compiled += 1;
        cache.note_compiled();
        if had_plans {
            stats.plan_shape_misses += 1;
            cache.note_shape_miss();
        }
    } else {
        // At capacity: the shape differs from everything cached.
        stats.plan_shape_misses += 1;
        cache.note_shape_miss();
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn t(shape: &[usize]) -> Value {
        Value::Tensor(Tensor::zeros(DType::F64, shape))
    }

    #[test]
    fn site_insert_find_roundtrip() {
        let s = Site::new();
        assert!(!s.has_plans());
        let key = fused_leaf_keys(&[t(&[2, 3]), Value::F64(1.0)]).unwrap();
        assert!(s.insert(key, KernelPlan::Fused(FusedPlan::Replay)));
        assert!(s.has_plans());
        // Same shapes, different scalar value: still a hit (kind-only key).
        let live = [t(&[2, 3]), Value::F64(42.0)];
        assert!(s.find(|k| fused_leaf_match(k, &live)).is_some());
        // Different shape: miss.
        let other = [t(&[3, 2]), Value::F64(1.0)];
        assert!(s.find(|k| fused_leaf_match(k, &other)).is_none());
        // Different dtype: miss.
        let f32s = [
            Value::Tensor(Tensor::zeros(DType::F32, &[2, 3])),
            Value::F64(1.0),
        ];
        assert!(s.find(|k| fused_leaf_match(k, &f32s)).is_none());
    }

    #[test]
    fn site_caps_plan_count() {
        let s = Site::new();
        for i in 0..MAX_PLANS_PER_SITE {
            let key = fused_leaf_keys(&[t(&[i + 1])]).unwrap();
            assert!(s.insert(key, KernelPlan::Opaque), "insert {i}");
        }
        let key = fused_leaf_keys(&[t(&[99])]).unwrap();
        assert!(!s.insert(key, KernelPlan::Opaque), "cap must hold");
    }

    #[test]
    fn structural_args_key_by_value() {
        let s = Site::new();
        let args = [t(&[4]), Value::I64(0)];
        let key = sized_keys(&args).unwrap();
        s.insert(key, KernelPlan::Opaque);
        assert!(s.find(|k| sized_match(k, &args)).is_some());
        // A different axis value is a different plan.
        let other = [t(&[4]), Value::I64(1)];
        assert!(s.find(|k| sized_match(k, &other)).is_none());
    }

    #[test]
    fn zerot_is_unkeyable() {
        assert!(fused_leaf_keys(&[Value::ZeroT]).is_none());
        assert!(sized_keys(&[t(&[1]), Value::ZeroT]).is_none());
    }

    #[test]
    fn concurrent_insert_and_find() {
        let s = std::sync::Arc::new(Site::new());
        let mut handles = Vec::new();
        for i in 0..8usize {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for r in 0..50usize {
                    let shape = [(i * 50 + r) % 7 + 1];
                    let live = [t(&shape)];
                    if s.find(|k| fused_leaf_match(k, &live)).is_none() {
                        let key = fused_leaf_keys(&live).unwrap();
                        s.insert(key, KernelPlan::Opaque);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // All 7 distinct shapes findable afterwards (dups are harmless).
        for d in 1..=7usize {
            let live = [t(&[d])];
            assert!(s.find(|k| fused_leaf_match(k, &live)).is_some(), "shape {d}");
        }
    }

    #[test]
    fn cache_env_and_override() {
        let c = PlanCache::new(2);
        c.set_enabled(false);
        assert!(c.site(0).is_none(), "disabled tier yields no sites");
        c.set_enabled(true);
        assert!(c.site(0).is_some());
        assert!(c.site(NO_SITE).is_none());
        assert!(c.site(5).is_none(), "out-of-range site");
        assert_eq!(c.stats(), PlanStats::default());
    }
}

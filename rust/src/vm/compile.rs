//! Graph → register bytecode compilation.
//!
//! Myia's VM executes graphs after *flat closure conversion*: each graph's
//! total free variables (§3's implicit nesting) become capture slots, filled
//! when the enclosing frame materializes the graph constant with
//! `MakeClosure`. Applications whose callee is a primitive constant compile
//! to direct `CallPrim` dispatch; an application in return position compiles
//! to `TailCall`, so the tail-recursive loops produced by the front end run
//! in constant stack space.

use super::value::Value;
use crate::ir::{Const, GraphId, Module, NodeId, Prim};
use std::collections::HashMap;
use std::sync::Arc;

/// Virtual register index within a frame.
pub type Reg = u32;

/// Bytecode instructions.
#[derive(Debug, Clone)]
pub enum Instr {
    /// Load a constant from the program constant pool.
    Const { dst: Reg, idx: usize },
    /// Materialize a closure over `code`, capturing the listed registers.
    MakeClosure { dst: Reg, code: usize, captures: Vec<Reg> },
    /// Direct primitive application. `last` is a bitmask over `args`: bit
    /// `j` set means this is the final read of `args[j]`'s register, so the
    /// interpreter *moves* the value out instead of cloning — which is what
    /// lets uniquely-owned tensor buffers be reused in place by the
    /// elementwise kernels (args beyond bit 31 are always cloned). `site` is
    /// this call's slot in the per-`Vm` shape-specialization plan cache
    /// (see `vm::plan`), or [`super::plan::NO_SITE`] for prims that never
    /// specialize.
    CallPrim { dst: Reg, prim: Prim, args: Vec<Reg>, last: u32, site: u32 },
    /// General call of a function value.
    Call { dst: Reg, func: Reg, args: Vec<Reg> },
    /// Call in return position: replaces the current frame.
    TailCall { func: Reg, args: Vec<Reg> },
    /// Return a register's value to the caller.
    Return { src: Reg },
    /// Execute a fused XLA segment (installed by the backend pass); the
    /// segment returns one value per destination register.
    XlaCall { dsts: Vec<Reg>, exec: usize, args: Vec<Reg> },
}

/// Compiled form of one graph.
#[derive(Debug)]
pub struct CodeObject {
    pub name: String,
    pub n_params: usize,
    pub n_captures: usize,
    pub n_regs: usize,
    pub instrs: Vec<Instr>,
}

/// A compiled program: all graphs reachable from the entry.
///
/// A `Program` is a pure compile-time artifact: once built it is never
/// mutated, so it is `Send + Sync` and can back any number of concurrent
/// invocations (each carrying its own per-call state).
#[derive(Debug, Default)]
pub struct Program {
    pub codes: Vec<Arc<CodeObject>>,
    pub consts: Vec<Value>,
    pub graph_code: HashMap<GraphId, usize>,
    /// Number of plan-eligible `CallPrim` sites (consecutively numbered
    /// across all code objects); sizes the `Vm`'s plan cache.
    pub plan_sites: usize,
}

/// Compilation error.
#[derive(Debug)]
pub struct CompileError(pub String);

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "vm compile error: {}", self.0)
    }
}

impl std::error::Error for CompileError {}

/// Compile every graph reachable from `entry`.
pub fn compile_program(m: &Module, entry: GraphId) -> Result<Program, CompileError> {
    let analysis = crate::ir::analyze(m, entry);
    let graphs = analysis.graphs.clone();
    let fv_map = analysis.fvs.clone();
    let mut program = Program::default();
    // Reserve code slots first so MakeClosure can forward-reference.
    for &g in &graphs {
        let idx = program.codes.len();
        program.codes.push(Arc::new(CodeObject {
            name: String::new(),
            n_params: 0,
            n_captures: 0,
            n_regs: 0,
            instrs: Vec::new(),
        }));
        program.graph_code.insert(g, idx);
    }
    for &g in &graphs {
        let code = compile_graph(m, g, &fv_map, analysis.order_of(g), &mut program)?;
        let idx = program.graph_code[&g];
        program.codes[idx] = Arc::new(code);
    }
    Ok(program)
}

fn compile_graph(
    m: &Module,
    g: GraphId,
    fv_map: &HashMap<GraphId, Vec<NodeId>>,
    order: &[NodeId],
    program: &mut Program,
) -> Result<CodeObject, CompileError> {
    let graph = m.graph(g);
    let params = graph.params.clone();
    let captures: Vec<NodeId> = fv_map.get(&g).cloned().unwrap_or_default();

    let mut c = Ctx {
        m,
        g,
        fv_map,
        program,
        regs: HashMap::new(),
        const_regs: HashMap::new(),
        closure_regs: HashMap::new(),
        next_reg: 0,
        instrs: Vec::new(),
    };
    for &p in &params {
        let r = c.alloc();
        c.regs.insert(p, r);
    }
    for &fv in &captures {
        let r = c.alloc();
        c.regs.insert(fv, r);
    }

    let ret = m
        .graph(g)
        .ret
        .ok_or_else(|| CompileError(format!("graph {} has no return", m.graph(g).name)))?;

    for &n in order {
        let is_ret = n == ret;
        let inputs = m.node(n).inputs().to_vec();
        // Callee forms.
        if let Some(p) = m.as_prim(inputs[0]) {
            let args: Vec<Reg> = inputs[1..]
                .iter()
                .map(|&a| c.reg_for(a))
                .collect::<Result<_, _>>()?;
            let dst = c.alloc();
            let site = if super::plan::plan_eligible(p) {
                let s = c.program.plan_sites as u32;
                c.program.plan_sites += 1;
                s
            } else {
                super::plan::NO_SITE
            };
            c.instrs.push(Instr::CallPrim { dst, prim: p, args, last: 0, site });
            c.regs.insert(n, dst);
        } else {
            if let Some(Const::Macro(op)) = m.node(inputs[0]).constant() {
                return Err(CompileError(format!(
                    "macro `{op}` reached the VM unexpanded; run the AD expansion pass first"
                )));
            }
            let func = c.reg_for(inputs[0])?;
            let args: Vec<Reg> = inputs[1..]
                .iter()
                .map(|&a| c.reg_for(a))
                .collect::<Result<_, _>>()?;
            if is_ret {
                c.instrs.push(Instr::TailCall { func, args });
                // TailCall never falls through; register map entry unneeded.
                c.regs.insert(n, u32::MAX);
            } else {
                let dst = c.alloc();
                c.instrs.push(Instr::Call { dst, func, args });
                c.regs.insert(n, dst);
            }
        }
    }

    // Emit Return unless the last instruction was the tail call for ret.
    let tail = matches!(c.instrs.last(), Some(Instr::TailCall { .. }))
        && m.node(ret).is_apply()
        && c.regs.get(&ret) == Some(&u32::MAX);
    if !tail {
        let src = c.reg_for(ret)?;
        c.instrs.push(Instr::Return { src });
    }

    mark_dying_prim_args(&mut c.instrs);

    Ok(CodeObject {
        name: graph.name.clone(),
        n_params: params.len(),
        n_captures: captures.len(),
        n_regs: c.next_reg as usize,
        instrs: c.instrs,
    })
}

/// Registers every instruction reads (bytecode is straight-line — all
/// control flow is calls — so "last read position" is exact liveness).
fn instr_reads(ins: &Instr) -> Vec<Reg> {
    match ins {
        Instr::Const { .. } => Vec::new(),
        Instr::MakeClosure { captures, .. } => captures.clone(),
        Instr::CallPrim { args, .. } | Instr::XlaCall { args, .. } => args.clone(),
        Instr::Call { func, args, .. } | Instr::TailCall { func, args } => {
            let mut v = vec![*func];
            v.extend_from_slice(args);
            v
        }
        Instr::Return { src } => vec![*src],
    }
}

/// Compute, per `CallPrim`, which argument registers die at that
/// instruction: the instruction is the register's final read and the
/// occurrence is the last within the argument list (so `mul(x, x)` moves
/// only the second read). The interpreter moves those values out of the
/// frame, which makes Arc refcount 1 an exact "this buffer is dead" proof
/// for the in-place elementwise kernels.
fn mark_dying_prim_args(instrs: &mut [Instr]) {
    let mut last_read: HashMap<Reg, usize> = HashMap::new();
    for (i, ins) in instrs.iter().enumerate() {
        for r in instr_reads(ins) {
            last_read.insert(r, i);
        }
    }
    for (i, ins) in instrs.iter_mut().enumerate() {
        if let Instr::CallPrim { args, last, .. } = ins {
            let mut mask = 0u32;
            for (j, &r) in args.iter().enumerate().take(32) {
                if last_read.get(&r) == Some(&i) && !args[j + 1..].contains(&r) {
                    mask |= 1 << j;
                }
            }
            *last = mask;
        }
    }
}

struct Ctx<'a> {
    m: &'a Module,
    g: GraphId,
    fv_map: &'a HashMap<GraphId, Vec<NodeId>>,
    program: &'a mut Program,
    regs: HashMap<NodeId, Reg>,
    const_regs: HashMap<NodeId, Reg>,
    closure_regs: HashMap<GraphId, Reg>,
    next_reg: Reg,
    instrs: Vec<Instr>,
}

impl<'a> Ctx<'a> {
    fn alloc(&mut self) -> Reg {
        let r = self.next_reg;
        self.next_reg += 1;
        r
    }

    /// Register holding the value of `n` in this frame.
    fn reg_for(&mut self, n: NodeId) -> Result<Reg, CompileError> {
        if let Some(&r) = self.regs.get(&n) {
            if r == u32::MAX {
                return Err(CompileError("use of tail-call result".into()));
            }
            return Ok(r);
        }
        if let Some(&r) = self.const_regs.get(&n) {
            return Ok(r);
        }
        let node = self.m.node(n);
        if let Some(c) = node.constant() {
            let r = match c {
                Const::Graph(h) => self.make_closure(*h)?,
                Const::Macro(op) => {
                    return Err(CompileError(format!(
                        "macro `{op}` reached the VM unexpanded; run the AD expansion pass first"
                    )))
                }
                other => {
                    let v = const_value(other);
                    let idx = self.program.consts.len();
                    self.program.consts.push(v);
                    let r = self.alloc();
                    self.instrs.push(Instr::Const { dst: r, idx });
                    r
                }
            };
            self.const_regs.insert(n, r);
            return Ok(r);
        }
        Err(CompileError(format!(
            "node {n} ({:?}) is not available in graph {} — owned by {:?}, captures {:?}",
            node.debug_name,
            self.m.graph(self.g).name,
            node.graph,
            self.fv_map.get(&self.g)
        )))
    }

    /// Emit (or reuse) a MakeClosure for graph `h` in the current frame.
    fn make_closure(&mut self, h: GraphId) -> Result<Reg, CompileError> {
        if let Some(&r) = self.closure_regs.get(&h) {
            return Ok(r);
        }
        let code = *self
            .program
            .graph_code
            .get(&h)
            .ok_or_else(|| CompileError(format!("graph {h} not in compilation set")))?;
        let fvs = self.fv_map.get(&h).cloned().unwrap_or_default();
        // Allocate dst BEFORE resolving captures that might themselves emit.
        let cap_regs: Vec<Reg> = fvs
            .iter()
            .map(|&fv| self.reg_for(fv))
            .collect::<Result<_, _>>()?;
        let dst = self.alloc();
        self.instrs.push(Instr::MakeClosure { dst, code, captures: cap_regs });
        // Only cache when the closure captures nothing that could differ —
        // within a single frame captures are SSA, so caching is always safe.
        self.closure_regs.insert(h, dst);
        Ok(dst)
    }
}

/// Convert an IR constant to a runtime value (graphs/macros handled above).
pub fn const_value(c: &Const) -> Value {
    match c {
        Const::Unit => Value::Unit,
        Const::F64(v) => Value::F64(*v),
        Const::I64(v) => Value::I64(*v),
        Const::Bool(b) => Value::Bool(*b),
        Const::Str(s) => Value::str(s.clone()),
        Const::Tensor(t) => Value::Tensor(t.clone()),
        Const::Prim(p) => Value::Prim(*p),
        Const::Key(k) => Value::Key(*k),
        Const::ZeroT => Value::ZeroT,
        Const::Fused(e) => Value::Fused(e.clone()),
        Const::Graph(_) | Const::Macro(_) => unreachable!("handled by compiler"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiles_simple_graph() {
        let mut m = Module::new();
        let f = m.add_graph("f");
        let x = m.add_parameter(f, "x");
        let r = m.apply_prim(f, Prim::Mul, &[x, x]);
        m.set_return(f, r);
        let p = compile_program(&m, f).unwrap();
        let code = &p.codes[p.graph_code[&f]];
        assert_eq!(code.n_params, 1);
        assert_eq!(code.n_captures, 0);
        assert!(matches!(code.instrs[0], Instr::CallPrim { prim: Prim::Mul, .. }));
        assert!(matches!(code.instrs.last(), Some(Instr::Return { .. })));
    }

    #[test]
    fn plan_sites_numbered_for_eligible_prims() {
        let mut m = Module::new();
        let f = m.add_graph("f");
        let x = m.add_parameter(f, "x");
        let mm = m.apply_prim(f, Prim::MatMul, &[x, x]);
        let sq = m.apply_prim(f, Prim::Mul, &[mm, mm]);
        let s = m.apply_prim(f, Prim::ReduceSum, &[sq]);
        m.set_return(f, s);
        let p = compile_program(&m, f).unwrap();
        assert_eq!(p.plan_sites, 2, "matmul + reduce_sum get sites; mul does not");
        let code = &p.codes[p.graph_code[&f]];
        let sites: Vec<(Prim, u32)> = code
            .instrs
            .iter()
            .filter_map(|i| match i {
                Instr::CallPrim { prim, site, .. } => Some((*prim, *site)),
                _ => None,
            })
            .collect();
        assert_eq!(
            sites,
            vec![
                (Prim::MatMul, 0),
                (Prim::Mul, super::super::plan::NO_SITE),
                (Prim::ReduceSum, 1),
            ]
        );
    }

    #[test]
    fn tail_call_in_return_position() {
        // f(x) = g(x); g(y) = y
        let mut m = Module::new();
        let g = m.add_graph("g");
        let y = m.add_parameter(g, "y");
        m.set_return(g, y);
        let f = m.add_graph("f");
        let x = m.add_parameter(f, "x");
        let gc = m.graph_constant(g);
        let call = m.apply(f, vec![gc, x]);
        m.set_return(f, call);

        let p = compile_program(&m, f).unwrap();
        let code = &p.codes[p.graph_code[&f]];
        assert!(
            code.instrs.iter().any(|i| matches!(i, Instr::TailCall { .. })),
            "{:?}",
            code.instrs
        );
        assert!(!code.instrs.iter().any(|i| matches!(i, Instr::Return { .. })));
    }

    #[test]
    fn closure_captures_compiled() {
        // f(x): g(y) = y + x; return g  — g captures x
        let mut m = Module::new();
        let f = m.add_graph("f");
        let x = m.add_parameter(f, "x");
        let g = m.add_graph("g");
        let y = m.add_parameter(g, "y");
        let b = m.apply_prim(g, Prim::Add, &[y, x]);
        m.set_return(g, b);
        let gc = m.graph_constant(g);
        m.set_return(f, gc);

        let p = compile_program(&m, f).unwrap();
        let fcode = &p.codes[p.graph_code[&f]];
        let gcode = &p.codes[p.graph_code[&g]];
        assert_eq!(gcode.n_captures, 1);
        assert!(fcode
            .instrs
            .iter()
            .any(|i| matches!(i, Instr::MakeClosure { captures, .. } if captures.len() == 1)));
    }

    #[test]
    fn unexpanded_macro_rejected() {
        use crate::ir::MacroOp;
        let mut m = Module::new();
        let f = m.add_graph("f");
        let x = m.add_parameter(f, "x");
        let mac = m.constant(Const::Macro(MacroOp::Grad));
        let sq = m.add_graph("sq");
        let y = m.add_parameter(sq, "y");
        let yy = m.apply_prim(sq, Prim::Mul, &[y, y]);
        m.set_return(sq, yy);
        let sqc = m.graph_constant(sq);
        let gradf = m.apply(f, vec![mac, sqc]);
        let call = m.apply(f, vec![gradf, x]);
        m.set_return(f, call);
        let err = compile_program(&m, f).unwrap_err();
        assert!(err.0.contains("unexpanded"), "{err}");
    }

    #[test]
    fn const_pool_shared() {
        let mut m = Module::new();
        let f = m.add_graph("f");
        let x = m.add_parameter(f, "x");
        let two = m.constant(Const::F64(2.0));
        let a = m.apply_prim(f, Prim::Mul, &[x, two]);
        let b = m.apply_prim(f, Prim::Add, &[a, two]);
        m.set_return(f, b);
        let p = compile_program(&m, f).unwrap();
        let code = &p.codes[p.graph_code[&f]];
        let const_loads = code.instrs.iter().filter(|i| matches!(i, Instr::Const { .. })).count();
        assert_eq!(const_loads, 1, "constant loaded once per frame");
    }
}

//! Myia's virtual machine.
//!
//! Graphs are compiled to register bytecode after flat closure conversion
//! ([`compile`]), then executed by an explicit-stack interpreter with proper
//! tail calls ([`exec`]). Primitive semantics live in [`prims`]; the runtime
//! value universe in [`value`]. The backend pass (see `crate::backend`)
//! replaces straight-line tensor regions with `XlaCall` instructions that
//! dispatch into compiled XLA executables — the paper's TVM role.

pub mod budget;
pub mod compile;
pub mod exec;
pub mod fused;
pub mod plan;
pub mod pool;
pub mod prims;
pub mod value;

pub use budget::{CancelToken, ExecBudget, Trap, TrapStats};
pub use compile::{compile_program, CodeObject, Instr, Program, Reg};
pub use exec::{ExecStats, SegmentRunner, Vm};
pub use plan::{PlanCache, PlanStats, NO_SITE};
pub use fused::eval_fused;
pub use prims::{eval_prim, eval_prim_inplace, gadd, zeros_like};
pub use value::{Closure, EnvMap, PartialApp, Value};

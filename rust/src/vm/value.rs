//! Runtime values.
//!
//! The VM's value universe mirrors the IR's type universe (§3): scalars,
//! tensors, tuples, first-class functions (closures and primitives), and the
//! AD environment values of §3.2. `ZeroT` is the symbolic zero tangent — the
//! additive identity of `gadd` — which keeps never-used gradient paths free.
//!
//! Values are `Send + Sync`: the language is purely functional (§3), so a
//! value is never mutated after construction and all shared ownership goes
//! through `Arc`. This is what lets one compiled [`crate::coordinator::Executable`]
//! be called from any number of threads at once.

use crate::ir::{FusedExpr, Prim};
use crate::tensor::{DType, Tensor};
use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

use super::compile::CodeObject;

/// AD environment: node-key → gradient contribution (§3.2).
pub type EnvMap = HashMap<u64, Value>;

/// A closure: compiled code plus captured values (flat closure conversion of
/// the graph's total free variables).
#[derive(Debug)]
pub struct Closure {
    pub code: Arc<CodeObject>,
    pub captures: Vec<Value>,
}

/// A partially-applied function (`partial(f, x)`).
#[derive(Debug)]
pub struct PartialApp {
    pub func: Value,
    pub bound: Vec<Value>,
}

/// A runtime value.
#[derive(Debug, Clone)]
pub enum Value {
    Unit,
    F64(f64),
    I64(i64),
    Bool(bool),
    Str(Arc<String>),
    Tensor(Tensor),
    Tuple(Arc<Vec<Value>>),
    Closure(Arc<Closure>),
    Prim(Prim),
    Partial(Arc<PartialApp>),
    Env(Arc<EnvMap>),
    Key(u64),
    ZeroT,
    /// A fused elementwise program (the first argument of `fused_map`);
    /// created only by the optimizer's fusion pass via `Const::Fused`.
    Fused(Arc<FusedExpr>),
}

impl Value {
    pub fn tuple(items: Vec<Value>) -> Value {
        Value::Tuple(Arc::new(items))
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(Arc::new(s.into()))
    }

    /// Type name for error messages.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Unit => "unit",
            Value::F64(_) => "f64",
            Value::I64(_) => "i64",
            Value::Bool(_) => "bool",
            Value::Str(_) => "str",
            Value::Tensor(_) => "tensor",
            Value::Tuple(_) => "tuple",
            Value::Closure(_) => "closure",
            Value::Prim(_) => "primitive",
            Value::Partial(_) => "partial",
            Value::Env(_) => "env",
            Value::Key(_) => "key",
            Value::ZeroT => "zero-tangent",
            Value::Fused(_) => "fused-expr",
        }
    }

    /// Is this a function-like value?
    pub fn is_callable(&self) -> bool {
        matches!(self, Value::Closure(_) | Value::Prim(_) | Value::Partial(_))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(v) => Some(*v),
            Value::I64(v) => Some(*v as f64),
            Value::Bool(b) => Some(*b as i64 as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::I64(v) => Some(*v),
            Value::Bool(b) => Some(*b as i64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_tensor(&self) -> Option<&Tensor> {
        match self {
            Value::Tensor(t) => Some(t),
            _ => None,
        }
    }

    /// Scalars are promoted to rank-0 tensors where a tensor is required.
    pub fn to_tensor(&self) -> Option<Tensor> {
        match self {
            Value::Tensor(t) => Some(t.clone()),
            Value::F64(v) => Some(Tensor::scalar_f64(*v)),
            Value::I64(v) => Some(Tensor::scalar_f64(*v as f64).cast(DType::I64)),
            Value::Bool(b) => Some(Tensor::scalar_f64(*b as i64 as f64).cast(DType::Bool)),
            _ => None,
        }
    }

    /// Structural equality (used by tests and the `eq` primitive on
    /// non-numeric data).
    pub fn structural_eq(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Unit, Value::Unit) => true,
            (Value::F64(a), Value::F64(b)) => a == b,
            (Value::I64(a), Value::I64(b)) => a == b,
            (Value::F64(a), Value::I64(b)) | (Value::I64(b), Value::F64(a)) => *a == *b as f64,
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::Tensor(a), Value::Tensor(b)) => a == b,
            (Value::Tuple(a), Value::Tuple(b)) => {
                a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.structural_eq(y))
            }
            (Value::Key(a), Value::Key(b)) => a == b,
            (Value::ZeroT, Value::ZeroT) => true,
            (Value::Prim(a), Value::Prim(b)) => a == b,
            (Value::Fused(a), Value::Fused(b)) => a == b,
            _ => false,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Unit => write!(f, "None"),
            Value::F64(v) => write!(f, "{v}"),
            Value::I64(v) => write!(f, "{v}"),
            Value::Bool(b) => write!(f, "{}", if *b { "True" } else { "False" }),
            Value::Str(s) => write!(f, "{s}"),
            Value::Tensor(t) => write!(f, "{}", t.to_display_string()),
            Value::Tuple(items) => {
                write!(f, "(")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{it}")?;
                }
                if items.len() == 1 {
                    write!(f, ",")?;
                }
                write!(f, ")")
            }
            Value::Closure(c) => write!(f, "<closure {}>", c.code.name),
            Value::Prim(p) => write!(f, "<primitive {p}>"),
            Value::Partial(p) => write!(f, "<partial {} (+{} bound)>", p.func, p.bound.len()),
            Value::Env(e) => write!(f, "<env with {} entries>", e.len()),
            Value::Key(k) => write!(f, "<key {k}>"),
            Value::ZeroT => write!(f, "<zero>"),
            Value::Fused(e) => write!(f, "<{e}>"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(Value::F64(1.5).as_f64(), Some(1.5));
        assert_eq!(Value::I64(3).as_f64(), Some(3.0));
        assert_eq!(Value::Bool(true).as_i64(), Some(1));
        assert_eq!(Value::Unit.as_f64(), None);
        let t = Value::I64(2).to_tensor().unwrap();
        assert_eq!(t.dtype(), DType::I64);
        assert_eq!(t.rank(), 0);
    }

    #[test]
    fn structural_equality() {
        let a = Value::tuple(vec![Value::F64(1.0), Value::I64(2)]);
        let b = Value::tuple(vec![Value::F64(1.0), Value::I64(2)]);
        let c = Value::tuple(vec![Value::F64(1.0)]);
        assert!(a.structural_eq(&b));
        assert!(!a.structural_eq(&c));
        assert!(Value::F64(2.0).structural_eq(&Value::I64(2)));
        assert!(Value::ZeroT.structural_eq(&Value::ZeroT));
    }

    #[test]
    fn display_forms() {
        assert_eq!(format!("{}", Value::Bool(true)), "True");
        assert_eq!(format!("{}", Value::Unit), "None");
        assert_eq!(
            format!("{}", Value::tuple(vec![Value::I64(1), Value::I64(2)])),
            "(1, 2)"
        );
        assert_eq!(format!("{}", Value::tuple(vec![Value::I64(1)])), "(1,)");
    }
}

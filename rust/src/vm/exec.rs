//! The bytecode interpreter.
//!
//! Frames live on an explicit heap stack, `TailCall` reuses the top frame, so
//! the tail-recursive loops emitted by the front end (and the deep
//! backpropagator chains built by reverse-mode AD) run without growing the
//! native stack.
//!
//! Thread safety: the [`Program`] (and the segment table) is immutable once
//! built — all per-call mutable state (registers, frames, closure
//! environments) lives in a per-invocation [`CallCtx`] allocated inside
//! [`Vm::call_value`]. The only shared mutable state in a [`Vm`] is the
//! statistics accumulator, kept in relaxed atomics so the calling path
//! takes no locks at all — `&Vm` calls are safe from any number of threads
//! concurrently.

use super::budget::{BudgetMeter, CancelToken, ExecBudget, Trap, TrapCell, TrapStats};
use super::compile::{CodeObject, Instr, Program, Reg};
use super::plan::{PlanCache, PlanStats, NO_SITE};
use super::prims::eval_prim_inplace;
use super::value::{Closure, Value};
use crate::ir::Prim;
use crate::ir::GraphId;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// A runner for a fused backend segment (installed by the XLA backend).
/// Runners are shared across concurrent invocations, hence `Send + Sync`.
pub trait SegmentRunner: Send + Sync {
    /// Execute the segment on argument values.
    fn run(&self, args: &[Value]) -> Result<Value>;
    /// Human-readable description (for metrics).
    fn describe(&self) -> String;
}

/// Execution statistics (metrics surface for the coordinator).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub instrs: u64,
    pub calls: u64,
    pub prim_calls: u64,
    pub max_depth: usize,
    pub xla_calls: u64,
    /// Fused elementwise kernels executed (`fused_map` dispatches).
    pub fused_ops: u64,
    /// Tensor allocations avoided by fused regions: eliminated
    /// intermediates plus outputs written in place of a dying operand.
    pub allocs_saved: u64,
    /// Full-buffer f64/f32 materializations (`as_f64_vec`-style round
    /// trips) performed inside primitive calls — zero across a fused
    /// region, the "conversion tax" the typed kernels eliminate.
    pub conversions: u64,
    /// Shape-specialized kernel plans compiled during this call (first
    /// sight of a shape key at a plan-eligible site; see `vm::plan`).
    pub plans_compiled: u64,
    /// Dispatches that matched a cached plan and skipped shape/dtype
    /// simulation entirely.
    pub plan_hits: u64,
    /// Dispatches at a site that had plans, none matching the live
    /// shapes (shape-polymorphic call site).
    pub plan_shape_misses: u64,
    /// Invocations trapped by the instruction-fuel ceiling of their
    /// [`ExecBudget`].
    pub fuel_exhausted: u64,
    /// Invocations trapped by the call-frame depth cap (budget or VM).
    pub depth_trapped: u64,
    /// Invocations trapped by the tensor-bytes ceiling.
    pub mem_trapped: u64,
    /// Invocations trapped by a deadline or explicit cancellation.
    pub deadline_exceeded: u64,
}

/// Lock-free statistics accumulator: per-call counters are folded in with
/// relaxed atomic adds, so concurrent serving threads never contend on a
/// lock for bookkeeping. Relaxed ordering is sufficient — the counters are
/// monotone telemetry, not synchronization.
#[derive(Default)]
struct StatsCell {
    instrs: AtomicU64,
    calls: AtomicU64,
    prim_calls: AtomicU64,
    max_depth: AtomicUsize,
    xla_calls: AtomicU64,
    fused_ops: AtomicU64,
    allocs_saved: AtomicU64,
    conversions: AtomicU64,
    plans_compiled: AtomicU64,
    plan_hits: AtomicU64,
    plan_shape_misses: AtomicU64,
    fuel_exhausted: AtomicU64,
    depth_trapped: AtomicU64,
    mem_trapped: AtomicU64,
    deadline_exceeded: AtomicU64,
}

impl StatsCell {
    fn merge(&self, s: &ExecStats) {
        self.instrs.fetch_add(s.instrs, Ordering::Relaxed);
        self.calls.fetch_add(s.calls, Ordering::Relaxed);
        self.prim_calls.fetch_add(s.prim_calls, Ordering::Relaxed);
        self.max_depth.fetch_max(s.max_depth, Ordering::Relaxed);
        self.xla_calls.fetch_add(s.xla_calls, Ordering::Relaxed);
        self.fused_ops.fetch_add(s.fused_ops, Ordering::Relaxed);
        self.allocs_saved.fetch_add(s.allocs_saved, Ordering::Relaxed);
        self.conversions.fetch_add(s.conversions, Ordering::Relaxed);
        self.plans_compiled.fetch_add(s.plans_compiled, Ordering::Relaxed);
        self.plan_hits.fetch_add(s.plan_hits, Ordering::Relaxed);
        self.plan_shape_misses.fetch_add(s.plan_shape_misses, Ordering::Relaxed);
        self.fuel_exhausted.fetch_add(s.fuel_exhausted, Ordering::Relaxed);
        self.depth_trapped.fetch_add(s.depth_trapped, Ordering::Relaxed);
        self.mem_trapped.fetch_add(s.mem_trapped, Ordering::Relaxed);
        self.deadline_exceeded.fetch_add(s.deadline_exceeded, Ordering::Relaxed);
    }

    fn take(&self) -> ExecStats {
        ExecStats {
            instrs: self.instrs.swap(0, Ordering::Relaxed),
            calls: self.calls.swap(0, Ordering::Relaxed),
            prim_calls: self.prim_calls.swap(0, Ordering::Relaxed),
            max_depth: self.max_depth.swap(0, Ordering::Relaxed),
            xla_calls: self.xla_calls.swap(0, Ordering::Relaxed),
            fused_ops: self.fused_ops.swap(0, Ordering::Relaxed),
            allocs_saved: self.allocs_saved.swap(0, Ordering::Relaxed),
            conversions: self.conversions.swap(0, Ordering::Relaxed),
            plans_compiled: self.plans_compiled.swap(0, Ordering::Relaxed),
            plan_hits: self.plan_hits.swap(0, Ordering::Relaxed),
            plan_shape_misses: self.plan_shape_misses.swap(0, Ordering::Relaxed),
            fuel_exhausted: self.fuel_exhausted.swap(0, Ordering::Relaxed),
            depth_trapped: self.depth_trapped.swap(0, Ordering::Relaxed),
            mem_trapped: self.mem_trapped.swap(0, Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.swap(0, Ordering::Relaxed),
        }
    }
}

/// The virtual machine: an immutable compiled program plus backend segment
/// table. Calling is `&self` and thread-safe; per-call state lives in a
/// [`CallCtx`].
pub struct Vm {
    pub program: Arc<Program>,
    pub segments: Vec<Arc<dyn SegmentRunner>>,
    pub max_depth: usize,
    stats: StatsCell,
    /// The shape-specialization tier: per-site, shape-keyed kernel plans
    /// shared (lock-free) by every thread calling through this `Vm`.
    plans: PlanCache,
    /// Cumulative budget-trap counters (never reset; see
    /// [`Vm::trap_stats`]).
    traps: TrapCell,
}

/// Per-invocation mutable state: the frame stack and this call's statistics.
/// One `CallCtx` is created per [`Vm::call_value`]; nothing in it is shared,
/// which is what makes concurrent calls on one `Vm` race-free.
struct CallCtx {
    stack: Vec<Frame>,
    stats: ExecStats,
}

impl CallCtx {
    fn new() -> CallCtx {
        CallCtx { stack: Vec::with_capacity(64), stats: ExecStats::default() }
    }
}

struct Frame {
    code: Arc<CodeObject>,
    regs: Vec<Value>,
    pc: usize,
    /// Register in the *caller's* frame receiving our return value.
    ret_dst: Reg,
}

/// Route one primitive call: `fused_map` goes to the single-loop fused
/// evaluator (with its savings folded into this call's statistics),
/// other plan-eligible prims at a numbered `CallPrim` site go through the
/// shape-specialization tier, everything else to the in-place-capable
/// evaluator. Conversion sampling lives here so every dispatch path —
/// `CallPrim`, `Call`/`TailCall` prim resolution, and top-level prim
/// values — attributes its `as_f64_vec` round-trips to
/// `ExecStats::conversions`.
fn dispatch_prim(
    p: Prim,
    args: &mut [Value],
    stats: &mut ExecStats,
    plans: &PlanCache,
    site: u32,
    token: Option<&CancelToken>,
) -> Result<Value> {
    crate::faultinject::error_at(crate::faultinject::Site::PrimEval)?;
    let conv_before = crate::tensor::conversion_count();
    let result = if p == Prim::FusedMap {
        stats.fused_ops += 1;
        super::fused::eval_fused_at(args, plans.site(site).map(|s| (plans, s)), stats, token).map(
            |(v, saved)| {
                stats.allocs_saved += saved;
                v
            },
        )
    } else if let Some(s) = plans.site(site) {
        super::plan::dispatch_sized(p, args, plans, s, stats)
    } else {
        eval_prim_inplace(p, args)
    };
    stats.conversions += crate::tensor::conversion_count() - conv_before;
    result
}

impl Frame {
    fn new(code: Arc<CodeObject>, captures: &[Value], args: Vec<Value>, ret_dst: Reg) -> Result<Frame> {
        if args.len() != code.n_params {
            bail!(
                "function `{}` expects {} arguments, got {}",
                code.name,
                code.n_params,
                args.len()
            );
        }
        let mut regs = Vec::with_capacity(code.n_regs);
        regs.extend(args);
        regs.extend_from_slice(captures);
        regs.resize(code.n_regs, Value::Unit);
        Ok(Frame { code, regs, pc: 0, ret_dst })
    }
}

impl Vm {
    pub fn new(program: Program) -> Vm {
        let plans = PlanCache::new(program.plan_sites);
        Vm {
            program: Arc::new(program),
            segments: Vec::new(),
            max_depth: 100_000,
            stats: StatsCell::default(),
            plans,
            traps: TrapCell::default(),
        }
    }

    /// Statistics accumulated since the last [`Vm::take_stats`].
    pub fn take_stats(&self) -> ExecStats {
        self.stats.take()
    }

    /// Cumulative shape-specialization counters (never reset).
    pub fn plan_stats(&self) -> PlanStats {
        self.plans.stats()
    }

    /// Cumulative budget-trap counters (never reset): invocations stopped
    /// by fuel, depth, memory, or deadline/cancellation ceilings.
    pub fn trap_stats(&self) -> TrapStats {
        self.traps.stats()
    }

    /// Force the shape-specialization tier on or off for this `Vm`
    /// (overrides the `MYIA_SPECIALIZE` decision taken at construction).
    pub fn set_specialization(&self, on: bool) {
        self.plans.set_enabled(on);
    }

    /// Is the shape-specialization tier active?
    pub fn specialization_enabled(&self) -> bool {
        self.plans.enabled()
    }

    /// Build the entry closure for a compiled graph (must capture nothing).
    pub fn closure_for(&self, g: GraphId) -> Result<Value> {
        let idx = *self
            .program
            .graph_code
            .get(&g)
            .ok_or_else(|| anyhow!("graph {g} was not compiled"))?;
        let code = self.program.codes[idx].clone();
        if code.n_captures != 0 {
            bail!("graph `{}` captures free variables and cannot be an entry point", code.name);
        }
        Ok(Value::Closure(Arc::new(Closure { code, captures: Vec::new() })))
    }

    /// Call a compiled graph by id.
    pub fn call_graph(&self, g: GraphId, args: Vec<Value>) -> Result<Value> {
        self.call_graph_with(g, args, &ExecBudget::default())
    }

    /// Call a compiled graph by id under a resource budget.
    pub fn call_graph_with(&self, g: GraphId, args: Vec<Value>, budget: &ExecBudget) -> Result<Value> {
        let f = self.closure_for(g)?;
        self.call_value_with(&f, args, budget)
    }

    /// Call any function value (closure, primitive, partial application).
    /// Thread-safe and lock-free: each invocation runs in its own
    /// [`CallCtx`]; the call's statistics are folded into the shared
    /// accumulator with relaxed atomic adds on completion.
    pub fn call_value(&self, f: &Value, args: Vec<Value>) -> Result<Value> {
        self.call_value_with(f, args, &ExecBudget::default())
    }

    /// [`Vm::call_value`] under a resource budget: exceeding any ceiling
    /// unwinds with a structured [`Trap`] error (recoverable via
    /// `anyhow::Error::downcast_ref::<Trap>`), which is also recorded in
    /// both the resettable [`ExecStats`] counters and the cumulative
    /// [`Vm::trap_stats`].
    pub fn call_value_with(&self, f: &Value, args: Vec<Value>, budget: &ExecBudget) -> Result<Value> {
        let mut ctx = CallCtx::new();
        let result = self.run(&mut ctx, f, args, budget);
        if let Err(e) = &result {
            if let Some(trap) = e.downcast_ref::<Trap>() {
                match trap {
                    Trap::FuelExhausted { .. } => ctx.stats.fuel_exhausted += 1,
                    Trap::DepthExceeded { .. } => ctx.stats.depth_trapped += 1,
                    Trap::MemExceeded { .. } => ctx.stats.mem_trapped += 1,
                    Trap::DeadlineExceeded | Trap::Cancelled => ctx.stats.deadline_exceeded += 1,
                }
                self.traps.record(trap);
            }
        }
        self.stats.merge(&ctx.stats);
        result
    }

    fn run(&self, ctx: &mut CallCtx, f: &Value, mut args: Vec<Value>, budget: &ExecBudget) -> Result<Value> {
        let mut meter = BudgetMeter::new(budget, self.max_depth);
        let CallCtx { stack, stats } = ctx;
        // Resolve non-closure callables without a frame.
        let mut func = f.clone();
        loop {
            match func {
                Value::Prim(p) => {
                    stats.prim_calls += 1;
                    let v = dispatch_prim(p, &mut args, stats, &self.plans, NO_SITE, meter.token())?;
                    meter.charge(&v)?;
                    return Ok(v);
                }
                Value::Partial(pa) => {
                    let mut combined = pa.bound.clone();
                    combined.extend(args);
                    args = combined;
                    func = pa.func.clone();
                }
                Value::Closure(_) => break,
                other => bail!("cannot call non-function value of type {}", other.type_name()),
            }
        }
        let closure = match func {
            Value::Closure(c) => c,
            _ => unreachable!(),
        };

        stack.push(Frame::new(closure.code.clone(), &closure.captures, args, 0)?);

        loop {
            let frame = stack.last_mut().expect("non-empty stack");
            let instr = &frame.code.instrs[frame.pc];
            frame.pc += 1;
            stats.instrs += 1;
            meter.step()?;
            match instr {
                Instr::Const { dst, idx } => {
                    frame.regs[*dst as usize] = self.program.consts[*idx].clone();
                }
                Instr::MakeClosure { dst, code, captures } => {
                    let cap: Vec<Value> =
                        captures.iter().map(|&r| frame.regs[r as usize].clone()).collect();
                    let code = self.program.codes[*code].clone();
                    frame.regs[*dst as usize] =
                        Value::Closure(Arc::new(Closure { code, captures: cap }));
                }
                Instr::CallPrim { dst, prim, args, last, site } => {
                    stats.prim_calls += 1;
                    // Hot path (§Perf): arity ≤ 4 covers every fixed-arity
                    // primitive; a stack buffer avoids a heap Vec per op.
                    // Dying registers (`last` bitmask, computed at compile
                    // time from exact straight-line liveness) are *moved*
                    // into the argument slots, so a uniquely-owned tensor
                    // buffer is provably dead and the elementwise kernels
                    // may write the result into it in place.
                    let v = if args.len() <= 4 {
                        let mut buf: [Value; 4] =
                            [Value::Unit, Value::Unit, Value::Unit, Value::Unit];
                        for (i, &r) in args.iter().enumerate() {
                            buf[i] = if last & (1 << i) != 0 {
                                std::mem::replace(&mut frame.regs[r as usize], Value::Unit)
                            } else {
                                frame.regs[r as usize].clone()
                            };
                        }
                        dispatch_prim(*prim, &mut buf[..args.len()], stats, &self.plans, *site, meter.token())
                    } else {
                        let mut argv: Vec<Value> = args
                            .iter()
                            .enumerate()
                            .map(|(i, &r)| {
                                if i < 32 && last & (1 << i) != 0 {
                                    std::mem::replace(&mut frame.regs[r as usize], Value::Unit)
                                } else {
                                    frame.regs[r as usize].clone()
                                }
                            })
                            .collect();
                        dispatch_prim(*prim, &mut argv, stats, &self.plans, *site, meter.token())
                    }
                    // Wrap with the function name for diagnostics — but pass
                    // budget traps through untouched so callers can still
                    // downcast them to `Trap`.
                    .map_err(|e| match e.downcast_ref::<Trap>() {
                        Some(_) => e,
                        None => anyhow!("in `{}`: {e}", frame.code.name),
                    })?;
                    meter.charge(&v)?;
                    frame.regs[*dst as usize] = v;
                }
                Instr::XlaCall { dsts, exec, args } => {
                    stats.xla_calls += 1;
                    let argv: Vec<Value> =
                        args.iter().map(|&r| frame.regs[r as usize].clone()).collect();
                    let seg = self
                        .segments
                        .get(*exec)
                        .ok_or_else(|| anyhow!("missing backend segment {exec}"))?;
                    let outs = seg.run(&argv)?;
                    let outs = match outs {
                        Value::Tuple(items) if dsts.len() > 1 => items.to_vec(),
                        single => vec![single],
                    };
                    if outs.len() != dsts.len() {
                        bail!("segment returned {} values for {} registers", outs.len(), dsts.len());
                    }
                    for (d, v) in dsts.iter().zip(outs) {
                        frame.regs[*d as usize] = v;
                    }
                }
                Instr::Call { dst, func, args } => {
                    stats.calls += 1;
                    let dst = *dst;
                    let callee = frame.regs[*func as usize].clone();
                    let mut argv: Vec<Value> =
                        args.iter().map(|&r| frame.regs[r as usize].clone()).collect();
                    // Resolve partial chains / prims inline.
                    let mut callee = callee;
                    loop {
                        match callee {
                            Value::Prim(p) => {
                                stats.prim_calls += 1;
                                let v = dispatch_prim(p, &mut argv, stats, &self.plans, NO_SITE, meter.token())?;
                                meter.charge(&v)?;
                                let frame = stack.last_mut().unwrap();
                                frame.regs[dst as usize] = v;
                                break;
                            }
                            Value::Partial(pa) => {
                                let mut combined = pa.bound.clone();
                                combined.extend(argv);
                                argv = combined;
                                callee = pa.func.clone();
                            }
                            Value::Closure(c) => {
                                meter.check_depth(stack.len())?;
                                let new = Frame::new(c.code.clone(), &c.captures, argv, dst)?;
                                stack.push(new);
                                break;
                            }
                            other => bail!(
                                "cannot call non-function value of type {} (in `{}`)",
                                other.type_name(),
                                stack.last().unwrap().code.name
                            ),
                        }
                    }
                    stats.max_depth = stats.max_depth.max(stack.len());
                }
                Instr::TailCall { func, args } => {
                    stats.calls += 1;
                    let callee = frame.regs[*func as usize].clone();
                    let mut argv: Vec<Value> =
                        args.iter().map(|&r| frame.regs[r as usize].clone()).collect();
                    let ret_dst = frame.ret_dst;
                    let mut callee = callee;
                    loop {
                        match callee {
                            Value::Prim(p) => {
                                stats.prim_calls += 1;
                                let v = dispatch_prim(p, &mut argv, stats, &self.plans, NO_SITE, meter.token())?;
                                meter.charge(&v)?;
                                stack.pop();
                                match stack.last_mut() {
                                    None => return Ok(v),
                                    Some(caller) => caller.regs[ret_dst as usize] = v,
                                }
                                break;
                            }
                            Value::Partial(pa) => {
                                let mut combined = pa.bound.clone();
                                combined.extend(argv);
                                argv = combined;
                                callee = pa.func.clone();
                            }
                            Value::Closure(c) => {
                                let new = Frame::new(c.code.clone(), &c.captures, argv, ret_dst)?;
                                *stack.last_mut().unwrap() = new;
                                break;
                            }
                            other => bail!("cannot tail-call value of type {}", other.type_name()),
                        }
                    }
                }
                Instr::Return { src } => {
                    let v = frame.regs[*src as usize].clone();
                    let ret_dst = frame.ret_dst;
                    stack.pop();
                    match stack.last_mut() {
                        None => return Ok(v),
                        Some(caller) => caller.regs[ret_dst as usize] = v,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::compile::compile_program;
    use super::*;
    use crate::ir::Module;
    use crate::parser::compile_source;

    /// Full pipeline helper: source → IR → bytecode → run.
    fn run(src: &str, entry: &str, args: Vec<Value>) -> Result<Value> {
        let mut m = Module::new();
        let graphs = compile_source(&mut m, src)?;
        let g = graphs[entry];
        let program = compile_program(&m, g).map_err(|e| anyhow!("{e}"))?;
        let vm = Vm::new(program);
        vm.call_graph(g, args)
    }

    fn runf(src: &str, entry: &str, args: &[f64]) -> f64 {
        let vals = args.iter().map(|&v| Value::F64(v)).collect();
        match run(src, entry, vals).unwrap() {
            Value::F64(v) => v,
            Value::I64(v) => v as f64,
            Value::Tensor(t) => t.item().unwrap(),
            other => panic!("expected number, got {other}"),
        }
    }

    #[test]
    fn arithmetic_expression() {
        assert_eq!(runf("def f(x):\n    return x ** 3 + 2 * x\n", "f", &[2.0]), 12.0);
    }

    #[test]
    fn conditionals() {
        let src = "def f(x):\n    if x > 0:\n        return x\n    else:\n        return -x\n";
        assert_eq!(runf(src, "f", &[3.0]), 3.0);
        assert_eq!(runf(src, "f", &[-3.0]), 3.0);
    }

    #[test]
    fn if_statement_with_merge() {
        let src = "def f(x):\n    y = 0.0\n    if x > 1.0:\n        y = x * 10.0\n    return y + 1.0\n";
        assert_eq!(runf(src, "f", &[2.0]), 21.0);
        assert_eq!(runf(src, "f", &[0.5]), 1.0);
    }

    #[test]
    fn while_loop_sums() {
        let src = "def f(n):\n    s = 0\n    i = 0\n    while i < n:\n        s = s + i\n        i = i + 1\n    return s\n";
        let r = run(src, "f", vec![Value::I64(10)]).unwrap();
        assert!(matches!(r, Value::I64(45)));
    }

    #[test]
    fn for_range_loop() {
        let src = "def f(n):\n    s = 1\n    for i in range(n):\n        s = s * 2\n    return s\n";
        let r = run(src, "f", vec![Value::I64(10)]).unwrap();
        assert!(matches!(r, Value::I64(1024)));
    }

    #[test]
    fn deep_loop_constant_stack() {
        // one million iterations: requires working tail calls
        let src = "def f(n):\n    i = 0\n    while i < n:\n        i = i + 1\n    return i\n";
        let r = run(src, "f", vec![Value::I64(1_000_000)]).unwrap();
        assert!(matches!(r, Value::I64(1_000_000)));
    }

    #[test]
    fn recursion_factorial() {
        let src = "def fact(n):\n    return 1 if n <= 1 else n * fact(n - 1)\n";
        let r = run(src, "fact", vec![Value::I64(10)]).unwrap();
        assert!(matches!(r, Value::I64(3628800)));
    }

    #[test]
    fn mutual_recursion() {
        let src = "def is_even(n):\n    return True if n == 0 else is_odd(n - 1)\n\ndef is_odd(n):\n    return False if n == 0 else is_even(n - 1)\n";
        let r = run(src, "is_even", vec![Value::I64(10)]).unwrap();
        assert!(matches!(r, Value::Bool(true)));
        let r = run(src, "is_even", vec![Value::I64(7)]).unwrap();
        assert!(matches!(r, Value::Bool(false)));
    }

    #[test]
    fn closures_capture() {
        let src = "def f(x):\n    def g(y):\n        return y + x\n    return g(10.0)\n";
        assert_eq!(runf(src, "f", &[5.0]), 15.0);
    }

    #[test]
    fn higher_order_functions() {
        let src = "\
def compose(f, g):
    def h(x):
        return f(g(x))
    return h

def double(x):
    return x * 2

def inc(x):
    return x + 1

def main(x):
    h = compose(double, inc)
    return h(x)
";
        assert_eq!(runf(src, "main", &[5.0]), 12.0);
    }

    #[test]
    fn returned_closure_over_loop_var() {
        let src = "\
def make_adder(n):
    return lambda x: x + n

def main(a):
    add3 = make_adder(3.0)
    return add3(a)
";
        assert_eq!(runf(src, "main", &[4.0]), 7.0);
    }

    #[test]
    fn cons_list_recursion() {
        // sum over a cons list built with list literal sugar
        let src = "\
def sum_list(xs):
    if is_nil(xs):
        return 0
    return xs[0] + sum_list(xs[1])

def main():
    return sum_list([1, 2, 3, 4])
";
        let r = run(src, "main", vec![]).unwrap();
        assert!(matches!(r, Value::I64(10)));
    }

    #[test]
    fn tree_recursion() {
        // binary tree as nested tuples: (left, right) or leaf number
        let src = "\
def tree_sum(t):
    if is_tuple_pair(t):
        return tree_sum(t[0]) + tree_sum(t[1])
    return t

def is_tuple_pair(t):
    return tuple_len_safe(t) == 2

def tuple_len_safe(t):
    return 0 if is_leaf(t) else len(t)

def is_leaf(t):
    return is_num(t)

def is_num(t):
    return not is_nil(t) and t == t and is_scalar(t)

def is_scalar(t):
    return True

def main():
    return 1
";
        // This test only checks the pipeline compiles deeply-nested defs;
        // the real tree model (with proper tags) lives in examples/.
        let r = run(src, "main", vec![]).unwrap();
        assert!(matches!(r, Value::I64(1)));
    }

    #[test]
    fn short_circuit_protects_recursion() {
        let src = "def f(n):\n    return n <= 0 or f(n - 1)\n";
        let r = run(src, "f", vec![Value::I64(100)]).unwrap();
        assert!(matches!(r, Value::Bool(true)));
    }

    #[test]
    fn tensors_through_language() {
        let src = "def f(w, x):\n    return sum(matmul(w, x))\n";
        let w = Value::Tensor(crate::tensor::Tensor::from_f64_shaped(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]).unwrap());
        let x = Value::Tensor(crate::tensor::Tensor::from_f64_shaped(vec![1.0, 1.0], vec![2]).unwrap());
        let r = run(src, "f", vec![w, x]).unwrap();
        match r {
            Value::Tensor(t) => assert_eq!(t.item().unwrap(), 10.0),
            other => panic!("{other}"),
        }
    }

    #[test]
    fn runtime_error_reports_function() {
        let src = "def f(x):\n    return x[0]\n";
        let e = run(src, "f", vec![Value::F64(1.0)]).unwrap_err();
        assert!(format!("{e}").contains("tuple"), "{e}");
    }

    #[test]
    fn arity_mismatch_reported() {
        let src = "def f(x, y):\n    return x\n";
        let e = run(src, "f", vec![Value::F64(1.0)]).unwrap_err();
        assert!(format!("{e}").contains("expects 2 arguments"), "{e}");
    }

    #[test]
    fn stats_collected() {
        let mut m = Module::new();
        let graphs = compile_source(&mut m, "def f(x):\n    return x * x + 1.0\n").unwrap();
        let g = graphs["f"];
        let program = compile_program(&m, g).unwrap();
        let vm = Vm::new(program);
        vm.call_graph(g, vec![Value::F64(2.0)]).unwrap();
        let stats = vm.take_stats();
        assert!(stats.instrs >= 3);
        assert!(stats.prim_calls >= 2);
        // stats reset after take
        assert_eq!(vm.take_stats().instrs, 0);
    }

    #[test]
    fn plan_tier_compiles_then_hits() {
        let mut m = Module::new();
        let graphs =
            compile_source(&mut m, "def f(w, x):\n    return sum(matmul(w, x))\n").unwrap();
        let g = graphs["f"];
        let program = compile_program(&m, g).unwrap();
        assert_eq!(program.plan_sites, 2, "matmul and sum are plan-eligible");
        let vm = Vm::new(program);
        vm.set_specialization(true);
        let w = Value::Tensor(
            crate::tensor::Tensor::from_f64_shaped(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]).unwrap(),
        );
        let x = Value::Tensor(
            crate::tensor::Tensor::from_f64_shaped(vec![1.0, 1.0], vec![2]).unwrap(),
        );
        vm.call_graph(g, vec![w.clone(), x.clone()]).unwrap();
        let first = vm.take_stats();
        assert_eq!(first.plans_compiled, 2, "both sites compile on first sight");
        assert_eq!(first.plan_hits, 0);
        vm.call_graph(g, vec![w.clone(), x.clone()]).unwrap();
        let second = vm.take_stats();
        assert_eq!(second.plan_hits, 2, "repeat shapes hit cached plans");
        assert_eq!(second.plans_compiled, 0);
        // A new shape at the same sites is a shape miss + recompile…
        let w3 = Value::Tensor(
            crate::tensor::Tensor::from_f64_shaped(vec![1.0; 9], vec![3, 3]).unwrap(),
        );
        let x3 = Value::Tensor(
            crate::tensor::Tensor::from_f64_shaped(vec![1.0; 3], vec![3]).unwrap(),
        );
        vm.call_graph(g, vec![w3.clone(), x3.clone()]).unwrap();
        let third = vm.take_stats();
        assert_eq!(third.plan_shape_misses, 2);
        assert_eq!(third.plans_compiled, 2);
        // …and then hits.
        vm.call_graph(g, vec![w3, x3]).unwrap();
        assert_eq!(vm.take_stats().plan_hits, 2);
        let cum = vm.plan_stats();
        assert_eq!(cum.plans_compiled, 4);
        assert_eq!(cum.plan_hits, 4);
        assert_eq!(cum.plan_shape_misses, 2);
        // Disabling the tier stops all plan activity but not execution.
        vm.set_specialization(false);
        vm.call_graph(g, vec![w, x]).unwrap();
        let off = vm.take_stats();
        assert_eq!(off.plan_hits + off.plans_compiled + off.plan_shape_misses, 0);
        assert_eq!(vm.plan_stats(), cum);
    }

    /// Compile one entry and return the (vm, graph) pair for budget tests.
    fn vm_for(src: &str, entry: &str) -> (Vm, GraphId) {
        let mut m = Module::new();
        let graphs = compile_source(&mut m, src).unwrap();
        let g = graphs[entry];
        let program = compile_program(&m, g).unwrap();
        (Vm::new(program), g)
    }

    #[test]
    fn budget_fuel_traps_runaway_loop() {
        let (vm, g) = vm_for(
            "def f(n):\n    i = 0\n    while i < n:\n        i = i + 1\n    return i\n",
            "f",
        );
        let budget = ExecBudget::default().with_fuel(10_000);
        let e = vm.call_graph_with(g, vec![Value::I64(100_000_000)], &budget).unwrap_err();
        match e.downcast_ref::<Trap>() {
            Some(Trap::FuelExhausted { limit: 10_000 }) => {}
            other => panic!("{other:?}: {e}"),
        }
        let stats = vm.take_stats();
        assert_eq!(stats.fuel_exhausted, 1);
        assert_eq!(vm.trap_stats().fuel_exhausted, 1);
        // The same call without a budget still succeeds (smaller n so the
        // test stays fast) and the cumulative trap counters don't move.
        vm.call_graph(g, vec![Value::I64(10)]).unwrap();
        assert_eq!(vm.trap_stats().fuel_exhausted, 1);
    }

    #[test]
    fn budget_deadline_cancels_unbounded_loop() {
        // `x + 1.0` never overflows (f64 saturates to inf), so this loop is
        // genuinely unbounded — only the deadline can stop it.
        let (vm, g) = vm_for(
            "def f(x):\n    while x > 0.0:\n        x = x + 1.0\n    return x\n",
            "f",
        );
        let budget = ExecBudget::default()
            .with_token(CancelToken::with_timeout(std::time::Duration::from_millis(30)));
        let e = vm.call_graph_with(g, vec![Value::F64(1.0)], &budget).unwrap_err();
        match e.downcast_ref::<Trap>() {
            Some(Trap::DeadlineExceeded) => {}
            other => panic!("{other:?}: {e}"),
        }
        assert_eq!(vm.trap_stats().deadline_exceeded, 1);
        assert_eq!(vm.take_stats().deadline_exceeded, 1);
    }

    #[test]
    fn budget_cancel_token_revokes_from_another_thread() {
        let (vm, g) = vm_for(
            "def f(x):\n    while x > 0.0:\n        x = x + 1.0\n    return x\n",
            "f",
        );
        let token = CancelToken::new();
        let t2 = token.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            t2.cancel();
        });
        let budget = ExecBudget::default().with_token(token);
        let e = vm.call_graph_with(g, vec![Value::F64(1.0)], &budget).unwrap_err();
        h.join().unwrap();
        match e.downcast_ref::<Trap>() {
            Some(Trap::Cancelled) => {}
            other => panic!("{other:?}: {e}"),
        }
        assert_eq!(vm.trap_stats().deadline_exceeded, 1);
    }

    #[test]
    fn budget_mem_ceiling_traps_allocation() {
        let (vm, g) = vm_for("def f(x):\n    return x + x\n", "f");
        let x = Value::Tensor(
            crate::tensor::Tensor::from_f64_shaped(vec![1.0; 64], vec![64]).unwrap(),
        );
        // 64 f64s = 512 bytes out; a 100-byte ceiling must trap…
        let tight = ExecBudget::default().with_max_tensor_bytes(100);
        let e = vm.call_graph_with(g, vec![x.clone()], &tight).unwrap_err();
        match e.downcast_ref::<Trap>() {
            Some(Trap::MemExceeded { limit: 100, .. }) => {}
            other => panic!("{other:?}: {e}"),
        }
        assert_eq!(vm.trap_stats().mem_trapped, 1);
        // …while a roomy one passes.
        let roomy = ExecBudget::default().with_max_tensor_bytes(1 << 20);
        vm.call_graph_with(g, vec![x], &roomy).unwrap();
        assert_eq!(vm.trap_stats().mem_trapped, 1);
    }

    #[test]
    fn budget_depth_cap_tightens_vm_limit() {
        let (vm, g) = vm_for("def f(n):\n    return 0 if n <= 0 else 1 + f(n - 1)\n", "f");
        let budget = ExecBudget::default().with_max_depth(50);
        let e = vm.call_graph_with(g, vec![Value::I64(1000)], &budget).unwrap_err();
        match e.downcast_ref::<Trap>() {
            Some(Trap::DepthExceeded { limit: 50 }) => {}
            other => panic!("{other:?}: {e}"),
        }
        assert_eq!(vm.trap_stats().depth_trapped, 1);
        assert_eq!(vm.take_stats().depth_trapped, 1);
        // Shallow recursion under the same budget completes normally.
        let r = vm.call_graph_with(g, vec![Value::I64(10)], &budget).unwrap();
        assert!(matches!(r, Value::I64(10)));
    }

    #[test]
    fn recursion_limit_enforced() {
        let mut m = Module::new();
        let graphs = compile_source(&mut m, "def f(x):\n    return 1 + f(x)\n").unwrap();
        let g = graphs["f"];
        let program = compile_program(&m, g).unwrap();
        let mut vm = Vm::new(program);
        vm.max_depth = 100;
        let e = vm.call_graph(g, vec![Value::F64(1.0)]).unwrap_err();
        assert!(format!("{e}").contains("recursion limit"), "{e}");
    }
}

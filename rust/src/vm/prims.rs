//! Evaluation rules for every primitive.
//!
//! Arithmetic primitives are polymorphic over scalars and tensors (with
//! NumPy broadcasting); `gadd`/`zeros_like` implement the generic tangent
//! arithmetic the AD transform relies on (§3.2); the env primitives carry
//! gradients of free variables; `switch` powers all lowered control flow.

use super::value::{EnvMap, PartialApp, Value};
use crate::ir::Prim;
use crate::tensor::{ops, DType, Rng, Tensor};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Evaluate a primitive on argument values.
pub fn eval_prim(p: Prim, args: &[Value]) -> Result<Value> {
    use Prim::*;
    if let Some(ar) = p.arity() {
        if args.len() != ar {
            bail!("{p} expects {ar} arguments, got {}", args.len());
        }
    }
    // Symbolic-zero propagation: backpropagator graphs are linear in the
    // incoming cotangent, so ZeroT absorbs through the linear positions of
    // the primitives they use (§3.2: unused gradients cost nothing).
    if args.iter().any(|a| matches!(a, Value::ZeroT)) {
        if let Some(v) = zerot_shortcut(p, args)? {
            return Ok(v);
        }
    }
    match p {
        Add | Sub | Mul | Div | Pow | Maximum | Minimum | FloorDiv | Mod => {
            numeric_binop(p, &args[0], &args[1])
        }
        Neg | Exp | Ln | Tanh | Sqrt | Sin | Cos | Relu | Sigmoid | Abs | Sign | Item
        | ScalarToTensor | CastF32 | CastF64 => numeric_unop(p, &args[0]),
        Lt | Gt | Le | Ge | Eq | Ne => compare(p, &args[0], &args[1]),
        Not => match &args[0] {
            Value::Bool(b) => Ok(Value::Bool(!b)),
            other => bail!("not_ expects bool, got {}", other.type_name()),
        },
        BoolAnd | BoolOr => match (&args[0], &args[1]) {
            (Value::Bool(a), Value::Bool(b)) => {
                Ok(Value::Bool(if p == BoolAnd { *a && *b } else { *a || *b }))
            }
            (a, b) => bail!("{p} expects bools, got {} and {}", a.type_name(), b.type_name()),
        },
        Switch => match &args[0] {
            Value::Bool(c) => Ok(if *c { args[1].clone() } else { args[2].clone() }),
            other => bail!("switch condition must be bool, got {}", other.type_name()),
        },
        MakeTuple => Ok(Value::tuple(args.to_vec())),
        TupleGetItem => {
            let items = as_tuple(&args[0], "tuple_getitem")?;
            let i = args[1]
                .as_i64()
                .ok_or_else(|| anyhow!("tuple index must be an integer"))?;
            let n = items.len() as i64;
            let idx = if i < 0 { i + n } else { i };
            if idx < 0 || idx >= n {
                bail!("tuple index {i} out of range for length {n}");
            }
            Ok(items[idx as usize].clone())
        }
        TupleLen => Ok(Value::I64(as_tuple(&args[0], "len")?.len() as i64)),
        TupleInject => {
            let i = args[0].as_i64().ok_or_else(|| anyhow!("tuple_inject index"))? as usize;
            let n = args[1].as_i64().ok_or_else(|| anyhow!("tuple_inject length"))? as usize;
            if i >= n {
                bail!("tuple_inject slot {i} out of range for length {n}");
            }
            let mut items = vec![Value::ZeroT; n];
            items[i] = args[2].clone();
            Ok(Value::tuple(items))
        }
        IsNil => Ok(Value::Bool(matches!(args[0], Value::Unit))),
        NewEnv => Ok(Value::Env(Arc::new(EnvMap::new()))),
        EnvSetItem => {
            let mut env: EnvMap = match &args[0] {
                Value::Env(e) => (**e).clone(),
                Value::ZeroT => EnvMap::new(),
                other => bail!("env_setitem expects env, got {}", other.type_name()),
            };
            let key = match &args[1] {
                Value::Key(k) => *k,
                other => bail!("env_setitem expects key, got {}", other.type_name()),
            };
            env.insert(key, args[2].clone());
            Ok(Value::Env(Arc::new(env)))
        }
        EnvGetItem => {
            let key = match &args[1] {
                Value::Key(k) => *k,
                other => bail!("env_getitem expects key, got {}", other.type_name()),
            };
            match &args[0] {
                Value::Env(e) => Ok(e.get(&key).cloned().unwrap_or(Value::ZeroT)),
                Value::ZeroT => Ok(Value::ZeroT),
                other => bail!("env_getitem expects env, got {}", other.type_name()),
            }
        }
        Gadd => gadd(&args[0], &args[1]),
        ZerosLike => Ok(zeros_like(&args[0])),
        OnesLike => ones_like(&args[0]),
        MatMul => {
            let a = need_tensor(&args[0], "matmul")?;
            let b = need_tensor(&args[1], "matmul")?;
            Ok(Value::Tensor(crate::tensor::matmul(&a, &b).map_err(err)?))
        }
        Transpose => {
            let a = need_tensor(&args[0], "transpose")?;
            Ok(Value::Tensor(ops::transpose(&a).map_err(err)?))
        }
        Reshape => {
            let a = need_tensor(&args[0], "reshape")?;
            let shape = shape_arg(&args[1])?;
            Ok(Value::Tensor(a.reshape(&shape).map_err(err)?))
        }
        BroadcastTo => {
            let a = need_tensor(&args[0], "broadcast_to")?;
            let shape = shape_arg(&args[1])?;
            Ok(Value::Tensor(ops::broadcast_to(&a, &shape).map_err(err)?))
        }
        SumTo => {
            let a = need_tensor(&args[0], "sum_to")?;
            let shape = shape_arg(&args[1])?;
            Ok(Value::Tensor(ops::sum_to(&a, &shape).map_err(err)?))
        }
        ShapeOf => {
            let a = need_tensor(&args[0], "shape")?;
            Ok(Value::tuple(a.shape().iter().map(|&d| Value::I64(d as i64)).collect()))
        }
        ReduceSum => {
            let a = need_tensor(&args[0], "sum")?;
            Ok(Value::Tensor(ops::reduce_sum_all(&a)))
        }
        ReduceMean => {
            let a = need_tensor(&args[0], "mean")?;
            Ok(Value::Tensor(ops::reduce_mean_all(&a)))
        }
        ReduceSumAxis => {
            let a = need_tensor(&args[0], "sum_axis")?;
            let axis = args[1].as_i64().ok_or_else(|| anyhow!("sum_axis axis"))? as usize;
            Ok(Value::Tensor(ops::reduce_sum_axis(&a, axis).map_err(err)?))
        }
        SoftmaxLast => {
            let a = need_tensor(&args[0], "softmax")?;
            Ok(Value::Tensor(ops::softmax_last(&a).map_err(err)?))
        }
        OneHot => {
            let a = need_tensor(&args[0], "one_hot")?;
            let depth = args[1].as_i64().ok_or_else(|| anyhow!("one_hot depth"))? as usize;
            Ok(Value::Tensor(ops::one_hot(&a, depth).map_err(err)?))
        }
        ArgmaxLast => {
            let a = need_tensor(&args[0], "argmax")?;
            Ok(Value::Tensor(ops::argmax_last(&a).map_err(err)?))
        }
        Concat0 => {
            let a = need_tensor(&args[0], "concat0")?;
            let b = need_tensor(&args[1], "concat0")?;
            Ok(Value::Tensor(ops::concat0(&[a, b]).map_err(err)?))
        }
        TakeRow => {
            let a = need_tensor(&args[0], "take_row")?;
            let i = args[1].as_i64().ok_or_else(|| anyhow!("take_row index"))? as usize;
            Ok(Value::Tensor(ops::take_row(&a, i).map_err(err)?))
        }
        Where => {
            let c = need_tensor(&args[0], "where_")?;
            let a = need_tensor(&args[1], "where_")?;
            let b = need_tensor(&args[2], "where_")?;
            Ok(Value::Tensor(ops::where_(&c, &a, &b).map_err(err)?))
        }
        Step => match &args[0] {
            Value::Tensor(t) => Ok(Value::Tensor(ops::step(t))),
            other => {
                let x = other
                    .as_f64()
                    .ok_or_else(|| anyhow!("step expects number, got {}", other.type_name()))?;
                Ok(Value::F64(if x > 0.0 { 1.0 } else { 0.0 }))
            }
        },
        SumToLike => sum_to_like(&args[0], &args[1]),
        BroadcastLike => broadcast_like(&args[0], &args[1]),
        SumLastKeep => {
            let a = need_tensor(&args[0], "sum_last_keep")?;
            Ok(Value::Tensor(ops::sum_last_keep(&a).map_err(err)?))
        }
        BatchMatMul => {
            let a = need_tensor(&args[0], "batch_matmul")?;
            let b = need_tensor(&args[1], "batch_matmul")?;
            let ab = flag_arg(&args[2], "batch_matmul a_batched")?;
            let bb = flag_arg(&args[3], "batch_matmul b_batched")?;
            Ok(Value::Tensor(crate::tensor::batch_matmul(&a, &b, ab, bb).map_err(err)?))
        }
        SumTail => {
            let a = need_tensor(&args[0], "sum_tail")?;
            Ok(Value::Tensor(ops::sum_tail(&a)))
        }
        BroadcastLead => {
            let v = need_tensor(&args[0], "broadcast_lead")?;
            let like = need_tensor(&args[1], "broadcast_lead")?;
            Ok(Value::Tensor(ops::broadcast_lead(&v, like.shape()).map_err(err)?))
        }
        SumToLead => {
            let d = need_tensor(&args[0], "sum_to_lead")?;
            let like = need_tensor(&args[1], "sum_to_lead")?;
            Ok(Value::Tensor(ops::sum_to_lead(&d, like.shape()).map_err(err)?))
        }
        SumToTail => {
            let d = need_tensor(&args[0], "sum_to_tail")?;
            // The target is the (unbatched) per-example operand; scalars
            // reduce to a per-example scalar.
            let target: Vec<usize> = match &args[1] {
                Value::Tensor(t) => t.shape().to_vec(),
                _ => Vec::new(),
            };
            Ok(Value::Tensor(ops::sum_to_tail(&d, &target).map_err(err)?))
        }
        BroadcastTail => {
            // Adjoint of sum_to_tail: spread/reduce `g` back to the shape of
            // the original batched gradient (`like`), batch axis pinned.
            let g = need_tensor(&args[0], "broadcast_tail")?;
            let like: Vec<usize> = match &args[1] {
                Value::Tensor(t) => t.shape().to_vec(),
                _ => Vec::new(),
            };
            Ok(Value::Tensor(ops::broadcast_tail(&g, &like).map_err(err)?))
        }
        MoveAxis => {
            let a = need_tensor(&args[0], "move_axis")?;
            let src = args[1].as_i64().ok_or_else(|| anyhow!("move_axis src axis"))? as usize;
            let dst = args[2].as_i64().ok_or_else(|| anyhow!("move_axis dst axis"))? as usize;
            Ok(Value::Tensor(ops::move_axis(&a, src, dst).map_err(err)?))
        }
        BroadcastBatch => {
            let v = need_tensor(&args[0], "broadcast_batch")?;
            let r = need_tensor(&args[1], "broadcast_batch")?;
            Ok(Value::Tensor(ops::broadcast_batch(&v, &r).map_err(err)?))
        }
        Print => {
            println!("{}", args[0]);
            Ok(args[0].clone())
        }
        Raise => {
            bail!("{}", args[0])
        }
        RngSplit => {
            let seed = args[0].as_i64().ok_or_else(|| anyhow!("rng_split seed"))? as u64;
            let (a, b) = split_seed(seed);
            Ok(Value::tuple(vec![Value::I64(a as i64), Value::I64(b as i64)]))
        }
        RngUniform | RngNormal => {
            let seed = args[0].as_i64().ok_or_else(|| anyhow!("rng seed"))? as u64;
            let shape = shape_arg(&args[1])?;
            let mut rng = Rng::new(seed);
            let t = if p == RngUniform {
                rng.uniform_tensor(&shape, 0.0, 1.0)
            } else {
                rng.normal_tensor(&shape, 1.0)
            };
            Ok(Value::Tensor(t))
        }
        Partial => Ok(Value::Partial(Arc::new(PartialApp {
            func: args[0].clone(),
            bound: vec![args[1].clone()],
        }))),
        FusedMap => {
            // Cold entry point (constant folding, segments, first-class
            // calls): clone the argument slots so the fused evaluator can
            // take ownership. The interpreter's hot path calls
            // `fused::eval_fused` directly with moved registers instead.
            let mut argv = args.to_vec();
            let (v, _saved) = super::fused::eval_fused(&mut argv)?;
            Ok(v)
        }
        MatMulEp => eval_matmul_ep(args),
    }
}

/// `matmul_ep(a, b, bias, a_batched, b_batched, code)` — a blocked matmul
/// with its bias-add + activation epilogue folded into the product's output
/// buffer (built by the `fusion` pass from `act(mm + bias)` chains).
///
/// `code` bits 0..=2 select the activation (0 none, 1 relu, 2 sigmoid,
/// 3 tanh); bit 3 records that the bias was the *left* operand of the add
/// (`bias + mm`), preserved for exact replay parity. Anything the fast
/// kernel declines — symbolic zeros, non-float or mixed dtypes, a bias the
/// product does not dominate — replays through the constituent primitives,
/// which is bit-for-bit the unfused semantics (shortcuts, promotions and
/// error messages included).
fn eval_matmul_ep(args: &[Value]) -> Result<Value> {
    let code = args[5]
        .as_i64()
        .ok_or_else(|| anyhow!("matmul_ep epilogue code must be an integer"))?;
    let act = match code & 7 {
        0 => None,
        1 => Some(Prim::Relu),
        2 => Some(Prim::Sigmoid),
        3 => Some(Prim::Tanh),
        c => bail!("matmul_ep: unknown activation code {c}"),
    };
    let bias_first = code & 8 != 0;
    let replay = || -> Result<Value> {
        let mm = eval_prim(
            Prim::BatchMatMul,
            &[args[0].clone(), args[1].clone(), args[3].clone(), args[4].clone()],
        )?;
        let sum = if bias_first {
            eval_prim(Prim::Add, &[args[2].clone(), mm])?
        } else {
            eval_prim(Prim::Add, &[mm, args[2].clone()])?
        };
        match act {
            Some(p) => eval_prim(p, &[sum]),
            None => Ok(sum),
        }
    };
    // Symbolic zeros flow through the replay's shortcut table (a ZeroT
    // operand zeroes the product, a ZeroT bias is the additive identity).
    if args[..3].iter().any(|v| matches!(v, Value::ZeroT)) {
        return replay();
    }
    let a = need_tensor(&args[0], "matmul_ep")?;
    let b = need_tensor(&args[1], "matmul_ep")?;
    let bias = need_tensor(&args[2], "matmul_ep")?;
    let ab = flag_arg(&args[3], "matmul_ep a_batched")?;
    let bb = flag_arg(&args[4], "matmul_ep b_batched")?;
    let un = act.map(|p| super::fused::un_op_of(p).expect("activation set above"));
    match crate::tensor::matmul_ep(&a, &b, &bias, ab, bb, un, bias_first).map_err(err)? {
        Some(t) => Ok(Value::Tensor(t)),
        None => replay(),
    }
}

/// Hot-path variant of [`eval_prim`]: the interpreter moves dying register
/// values into `args`, so elementwise arithmetic can consume its operands
/// and write the result in place of a uniquely-owned buffer (see
/// `tensor/ops.rs`). Semantics are identical to [`eval_prim`] — everything
/// that is not owned-tensor arithmetic delegates to it.
pub fn eval_prim_inplace(p: Prim, args: &mut [Value]) -> Result<Value> {
    use Prim::*;
    if args.iter().any(|a| matches!(a, Value::ZeroT)) {
        // Symbolic zeros take the shortcut table; no reuse opportunity.
        return eval_prim(p, args);
    }
    match p {
        Add | Sub | Mul | Div | Pow | Maximum | Minimum | FloorDiv | Mod
            if args.len() == 2
                && (matches!(args[0], Value::Tensor(_)) || matches!(args[1], Value::Tensor(_))) =>
        {
            let op = super::fused::num_op_of(p).expect("arithmetic prim");
            let a = take_tensor(&mut args[0], p.name())?;
            let b = take_tensor(&mut args[1], p.name())?;
            Ok(Value::Tensor(ops::binary_num_owned(a, b, op).map_err(err)?))
        }
        // Tensor ⊕ tensor gradient accumulation is plain addition — the
        // single hottest op in adjoint programs.
        Gadd if args.len() == 2
            && matches!(args[0], Value::Tensor(_))
            && matches!(args[1], Value::Tensor(_)) =>
        {
            let a = take_tensor(&mut args[0], "gadd")?;
            let b = take_tensor(&mut args[1], "gadd")?;
            Ok(Value::Tensor(ops::binary_num_owned(a, b, ops::NumOp::Add).map_err(err)?))
        }
        Neg | Exp | Ln | Tanh | Sqrt | Sin | Cos | Relu | Sigmoid | Abs | Sign | Step
            if args.len() == 1 && matches!(args[0], Value::Tensor(_)) =>
        {
            let op = super::fused::un_op_of(p).expect("unary prim");
            let a = take_tensor(&mut args[0], p.name())?;
            Ok(Value::Tensor(ops::unary_num_owned(a, op)))
        }
        Where
            if args.len() == 3
                && (matches!(args[1], Value::Tensor(_)) || matches!(args[2], Value::Tensor(_))) =>
        {
            let c = take_tensor(&mut args[0], "where_")?;
            let a = take_tensor(&mut args[1], "where_")?;
            let b = take_tensor(&mut args[2], "where_")?;
            Ok(Value::Tensor(ops::where_owned(c, a, b).map_err(err)?))
        }
        _ => eval_prim(p, args),
    }
}

/// Move a tensor out of an argument slot (scalars promote to rank-0).
fn take_tensor(v: &mut Value, what: &str) -> Result<Tensor> {
    match std::mem::replace(v, Value::Unit) {
        Value::Tensor(t) => Ok(t),
        other => other.to_tensor().ok_or_else(|| {
            anyhow!("{what} expects a tensor (or scalar), got {}", other.type_name())
        }),
    }
}

fn err(e: crate::tensor::TensorError) -> anyhow::Error {
    anyhow!("{e}")
}

/// ZeroT absorption rules for the linear positions of primitives.
/// Returns `Ok(None)` when the primitive has no shortcut (normal evaluation
/// proceeds and may legitimately error).
fn zerot_shortcut(p: Prim, args: &[Value]) -> Result<Option<Value>> {
    use Prim::*;
    let z = |i: usize| matches!(args.get(i), Some(Value::ZeroT));
    Ok(match p {
        // Linear unary ops.
        Neg | Transpose | ReduceSum | ReduceMean | SumLastKeep | Item | ScalarToTensor
        | CastF32 | CastF64 | ReduceSumAxis if z(0) => Some(Value::ZeroT),
        // ZeroT times / through anything is ZeroT.
        Mul | MatMul if z(0) || z(1) => Some(Value::ZeroT),
        Div if z(0) => Some(Value::ZeroT),
        // ZeroT is the additive identity.
        Add if z(0) => Some(args[1].clone()),
        Add if z(1) => Some(args[0].clone()),
        Sub if z(1) => Some(args[0].clone()),
        Sub if z(0) => Some(numeric_unop(Neg, &args[1])?),
        // Shape ops on a zero cotangent stay zero.
        Reshape | BroadcastTo | SumTo | TupleGetItem if z(0) => Some(Value::ZeroT),
        // The batching kernels are linear in their data operand.
        SumTail | BroadcastLead | SumToLead | SumToTail | BroadcastTail | MoveAxis
        | BroadcastBatch
            if z(0) =>
        {
            Some(Value::ZeroT)
        }
        BatchMatMul if z(0) || z(1) => Some(Value::ZeroT),
        _ => None,
    })
}

/// Batch flags for `batch_matmul` (constant bools baked in by Vmap, but
/// runtime values in the shared ▶/◀ prim graphs).
fn flag_arg(v: &Value, what: &str) -> Result<bool> {
    match v {
        Value::Bool(b) => Ok(*b),
        Value::I64(i) => Ok(*i != 0),
        other => bail!("{what} expects a bool, got {}", other.type_name()),
    }
}

fn as_tuple<'v>(v: &'v Value, what: &str) -> Result<&'v Arc<Vec<Value>>> {
    match v {
        Value::Tuple(items) => Ok(items),
        other => bail!("{what} expects a tuple, got {}", other.type_name()),
    }
}

fn need_tensor(v: &Value, what: &str) -> Result<Tensor> {
    v.to_tensor()
        .ok_or_else(|| anyhow!("{what} expects a tensor (or scalar), got {}", v.type_name()))
}

/// Shape tuples are tuples of non-negative integers.
fn shape_arg(v: &Value) -> Result<Vec<usize>> {
    let items = as_tuple(v, "shape argument")?;
    items
        .iter()
        .map(|it| {
            it.as_i64()
                .filter(|&d| d >= 0)
                .map(|d| d as usize)
                .ok_or_else(|| anyhow!("shape entries must be non-negative integers, got {it}"))
        })
        .collect()
}

/// `sum_to_like(d, x)`: reduce `d` down to the shape of `x` — the adjoint of
/// implicit broadcasting in binary ops. ZeroT passes through.
fn sum_to_like(d: &Value, x: &Value) -> Result<Value> {
    if matches!(d, Value::ZeroT) {
        return Ok(Value::ZeroT);
    }
    match x {
        Value::Tensor(xt) => {
            let dt = need_tensor(d, "sum_to_like")?;
            if dt.shape() == xt.shape() {
                return Ok(Value::Tensor(dt));
            }
            if dt.rank() < xt.rank() {
                // Gradient already smaller (degenerate); broadcast up.
                return Ok(Value::Tensor(ops::broadcast_to(&dt, xt.shape()).map_err(err)?));
            }
            Ok(Value::Tensor(ops::sum_to(&dt, xt.shape()).map_err(err)?))
        }
        // Scalar target: total sum.
        _ => match d {
            Value::Tensor(dt) => Ok(Value::F64(ops::reduce_sum_all(dt).item().map_err(err)?)),
            other => Ok(other.clone()),
        },
    }
}

/// `broadcast_like(v, t)`: broadcast `v` to the shape of `t` — the adjoint of
/// `sum_to_like`.
fn broadcast_like(v: &Value, t: &Value) -> Result<Value> {
    if matches!(v, Value::ZeroT) {
        return Ok(Value::ZeroT);
    }
    match t {
        Value::Tensor(tt) => {
            let vt = need_tensor(v, "broadcast_like")?;
            Ok(Value::Tensor(ops::broadcast_to(&vt, tt.shape()).map_err(err)?))
        }
        _ => match v {
            Value::Tensor(vt) => Ok(Value::F64(vt.item().map_err(err)?)),
            other => Ok(other.clone()),
        },
    }
}

/// SplitMix64-style seed derivation for `rng_split`.
fn split_seed(seed: u64) -> (u64, u64) {
    fn mix(mut z: u64) -> u64 {
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    let a = mix(seed.wrapping_add(0x9E3779B97F4A7C15));
    let b = mix(seed.wrapping_add(0x3C6EF372FE94F82A));
    (a | 1, b | 1)
}

fn both_int(a: &Value, b: &Value) -> Option<(i64, i64)> {
    match (a, b) {
        (Value::I64(x), Value::I64(y)) => Some((*x, *y)),
        (Value::I64(x), Value::Bool(y)) => Some((*x, *y as i64)),
        (Value::Bool(x), Value::I64(y)) => Some((*x as i64, *y)),
        (Value::Bool(x), Value::Bool(y)) => Some((*x as i64, *y as i64)),
        _ => None,
    }
}

fn numeric_binop(p: Prim, a: &Value, b: &Value) -> Result<Value> {
    use Prim::*;
    // Tensor path if either side is a tensor.
    if matches!(a, Value::Tensor(_)) || matches!(b, Value::Tensor(_)) {
        let ta = need_tensor(a, p.name())?;
        let tb = need_tensor(b, p.name())?;
        let r = match p {
            Add => ops::add(&ta, &tb),
            Sub => ops::sub(&ta, &tb),
            Mul => ops::mul(&ta, &tb),
            Div => ops::div(&ta, &tb),
            Pow => ops::pow(&ta, &tb),
            Maximum => ops::maximum(&ta, &tb),
            Minimum => ops::minimum(&ta, &tb),
            // Typed kernels: i64 floordiv/mod use the same exact Euclidean
            // forms as the scalar path instead of an f64 round-trip.
            FloorDiv => ops::binary_num(&ta, &tb, ops::NumOp::FloorDiv),
            Mod => ops::binary_num(&ta, &tb, ops::NumOp::Mod),
            _ => unreachable!(),
        }
        .map_err(err)?;
        return Ok(Value::Tensor(r));
    }
    // Integer-preserving scalar path.
    if let Some((x, y)) = both_int(a, b) {
        let v = match p {
            Add => Value::I64(x.wrapping_add(y)),
            Sub => Value::I64(x.wrapping_sub(y)),
            Mul => Value::I64(x.wrapping_mul(y)),
            Div => {
                if y == 0 {
                    bail!("division by zero");
                }
                Value::F64(x as f64 / y as f64)
            }
            FloorDiv => {
                if y == 0 {
                    bail!("integer division by zero");
                }
                Value::I64(x.div_euclid(y))
            }
            Mod => {
                if y == 0 {
                    bail!("modulo by zero");
                }
                Value::I64(x.rem_euclid(y))
            }
            Pow => {
                if y >= 0 {
                    Value::I64(x.pow(y.min(u32::MAX as i64) as u32))
                } else {
                    // Clamp before the i32 cast: a huge negative exponent
                    // must saturate toward 0, not wrap positive.
                    Value::F64((x as f64).powi(y.max(i32::MIN as i64) as i32))
                }
            }
            Maximum => Value::I64(x.max(y)),
            Minimum => Value::I64(x.min(y)),
            _ => unreachable!(),
        };
        return Ok(v);
    }
    // Float scalar path.
    let (x, y) = match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => (x, y),
        _ => bail!("{} expects numbers, got {} and {}", p.name(), a.type_name(), b.type_name()),
    };
    let v = match p {
        Add => x + y,
        Sub => x - y,
        Mul => x * y,
        Div => x / y,
        Pow => x.powf(y),
        Maximum => x.max(y),
        Minimum => x.min(y),
        FloorDiv => (x / y).floor(),
        Mod => x.rem_euclid(y),
        _ => unreachable!(),
    };
    Ok(Value::F64(v))
}

fn numeric_unop(p: Prim, a: &Value) -> Result<Value> {
    use Prim::*;
    match p {
        Item => {
            let t = need_tensor(a, "item")?;
            return Ok(Value::F64(t.item().map_err(err)?));
        }
        ScalarToTensor => {
            return Ok(Value::Tensor(need_tensor(a, "to_tensor")?));
        }
        CastF32 => {
            return Ok(Value::Tensor(need_tensor(a, "cast_f32")?.cast(DType::F32)));
        }
        CastF64 => {
            return Ok(Value::Tensor(need_tensor(a, "cast_f64")?.cast(DType::F64)));
        }
        _ => {}
    }
    if let Value::Tensor(t) = a {
        let r = match p {
            Neg => ops::neg(t),
            Exp => ops::exp(t),
            Ln => ops::ln(t),
            Tanh => ops::tanh(t),
            Sqrt => ops::sqrt(t),
            Sin => ops::sin(t),
            Cos => ops::cos(t),
            Relu => ops::relu(t),
            Sigmoid => ops::sigmoid(t),
            Abs => ops::abs(t),
            Sign => ops::sign(t),
            _ => unreachable!(),
        };
        return Ok(Value::Tensor(r));
    }
    if p == Neg {
        if let Value::I64(v) = a {
            return Ok(Value::I64(-v));
        }
    }
    if p == Abs {
        if let Value::I64(v) = a {
            return Ok(Value::I64(v.abs()));
        }
    }
    let x = a
        .as_f64()
        .ok_or_else(|| anyhow!("{} expects a number, got {}", p.name(), a.type_name()))?;
    let v = match p {
        Neg => -x,
        Exp => x.exp(),
        Ln => x.ln(),
        Tanh => x.tanh(),
        Sqrt => x.sqrt(),
        Sin => x.sin(),
        Cos => x.cos(),
        Relu => x.max(0.0),
        Sigmoid => 1.0 / (1.0 + (-x).exp()),
        Abs => x.abs(),
        Sign => x.signum(),
        _ => unreachable!(),
    };
    Ok(Value::F64(v))
}

fn compare(p: Prim, a: &Value, b: &Value) -> Result<Value> {
    use Prim::*;
    if matches!(a, Value::Tensor(_)) || matches!(b, Value::Tensor(_)) {
        let ta = need_tensor(a, p.name())?;
        let tb = need_tensor(b, p.name())?;
        let r = match p {
            Lt => ops::lt(&ta, &tb),
            Gt => ops::gt(&ta, &tb),
            Le => ops::le(&ta, &tb),
            Ge => ops::ge(&ta, &tb),
            Eq => ops::eq(&ta, &tb),
            Ne => ops::ne(&ta, &tb),
            _ => unreachable!(),
        }
        .map_err(err)?;
        return Ok(Value::Tensor(r));
    }
    // Structural equality for non-numeric values.
    if matches!(p, Eq | Ne) && (a.as_f64().is_none() || b.as_f64().is_none()) {
        let eq = a.structural_eq(b);
        return Ok(Value::Bool(if p == Eq { eq } else { !eq }));
    }
    let (x, y) = match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => (x, y),
        _ => bail!("{} expects numbers, got {} and {}", p.name(), a.type_name(), b.type_name()),
    };
    let v = match p {
        Lt => x < y,
        Gt => x > y,
        Le => x <= y,
        Ge => x >= y,
        Eq => x == y,
        Ne => x != y,
        _ => unreachable!(),
    };
    Ok(Value::Bool(v))
}

/// Generic gradient addition (§3.2): the monoid over tangents. `ZeroT` is
/// the identity; tuples add elementwise; envs merge with recursive `gadd`.
pub fn gadd(a: &Value, b: &Value) -> Result<Value> {
    match (a, b) {
        (Value::ZeroT, x) | (x, Value::ZeroT) => Ok(x.clone()),
        (Value::Unit, Value::Unit) => Ok(Value::Unit),
        (Value::Tuple(xs), Value::Tuple(ys)) => {
            if xs.len() != ys.len() {
                bail!("gadd tuple length mismatch: {} vs {}", xs.len(), ys.len());
            }
            let items: Result<Vec<Value>> =
                xs.iter().zip(ys.iter()).map(|(x, y)| gadd(x, y)).collect();
            Ok(Value::tuple(items?))
        }
        (Value::Env(x), Value::Env(y)) => {
            let mut out = (**x).clone();
            for (k, v) in y.iter() {
                let merged = match out.get(k) {
                    Some(existing) => gadd(existing, v)?,
                    None => v.clone(),
                };
                out.insert(*k, merged);
            }
            Ok(Value::Env(Arc::new(out)))
        }
        _ => numeric_binop(Prim::Add, a, b)
            .map_err(|_| anyhow!("gadd cannot combine {} and {}", a.type_name(), b.type_name())),
    }
}

/// Zero tangent with the structure of `x`.
pub fn zeros_like(x: &Value) -> Value {
    match x {
        Value::F64(_) => Value::F64(0.0),
        Value::I64(_) => Value::I64(0),
        Value::Bool(_) => Value::Bool(false),
        Value::Tensor(t) => Value::Tensor(Tensor::zeros(t.dtype(), t.shape())),
        Value::Tuple(items) => Value::tuple(items.iter().map(zeros_like).collect()),
        // The gradient of a function value is an env of free-variable
        // gradients; its zero is the empty env.
        Value::Closure(_) | Value::Prim(_) | Value::Partial(_) => Value::Env(Arc::new(EnvMap::new())),
        Value::Env(_) => Value::Env(Arc::new(EnvMap::new())),
        Value::Unit | Value::Str(_) | Value::Key(_) | Value::Fused(_) => Value::Unit,
        Value::ZeroT => Value::ZeroT,
    }
}

fn ones_like(x: &Value) -> Result<Value> {
    Ok(match x {
        Value::F64(_) => Value::F64(1.0),
        Value::I64(_) => Value::I64(1),
        Value::Tensor(t) => Value::Tensor(Tensor::ones(t.dtype(), t.shape())),
        Value::Tuple(items) => {
            let v: Result<Vec<Value>> = items.iter().map(ones_like).collect();
            Value::tuple(v?)
        }
        other => bail!("ones_like of {}", other.type_name()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(p: Prim, args: &[Value]) -> Value {
        eval_prim(p, args).unwrap()
    }

    #[test]
    fn scalar_arithmetic() {
        assert!(matches!(ev(Prim::Add, &[Value::I64(2), Value::I64(3)]), Value::I64(5)));
        assert!(matches!(ev(Prim::Div, &[Value::I64(7), Value::I64(2)]), Value::F64(v) if v == 3.5));
        assert!(matches!(ev(Prim::FloorDiv, &[Value::I64(7), Value::I64(2)]), Value::I64(3)));
        assert!(matches!(ev(Prim::Pow, &[Value::I64(2), Value::I64(10)]), Value::I64(1024)));
        assert!(matches!(ev(Prim::Pow, &[Value::F64(2.0), Value::F64(0.5)]), Value::F64(_)));
        assert!(matches!(ev(Prim::Mod, &[Value::I64(-7), Value::I64(3)]), Value::I64(2)));
        assert!(eval_prim(Prim::Div, &[Value::I64(1), Value::I64(0)]).is_err());
    }

    #[test]
    fn mixed_scalar_tensor() {
        let t = Value::Tensor(Tensor::from_f64(&[1.0, 2.0]));
        let r = ev(Prim::Mul, &[t.clone(), Value::F64(3.0)]);
        match r {
            Value::Tensor(t) => assert_eq!(t.as_f64_vec(), vec![3.0, 6.0]),
            other => panic!("{other:?}"),
        }
        let r = ev(Prim::Lt, &[t, Value::F64(1.5)]);
        assert!(matches!(r, Value::Tensor(ref t) if t.dtype() == DType::Bool));
    }

    #[test]
    fn comparisons_and_bools() {
        assert!(matches!(ev(Prim::Lt, &[Value::I64(1), Value::I64(2)]), Value::Bool(true)));
        assert!(matches!(ev(Prim::Eq, &[Value::Unit, Value::Unit]), Value::Bool(true)));
        assert!(matches!(ev(Prim::Ne, &[Value::str("a"), Value::str("b")]), Value::Bool(true)));
        assert!(matches!(ev(Prim::Not, &[Value::Bool(false)]), Value::Bool(true)));
        assert!(eval_prim(Prim::Not, &[Value::I64(1)]).is_err());
    }

    #[test]
    fn switch_selects() {
        let r = ev(Prim::Switch, &[Value::Bool(true), Value::I64(1), Value::I64(2)]);
        assert!(matches!(r, Value::I64(1)));
        let r = ev(Prim::Switch, &[Value::Bool(false), Value::I64(1), Value::I64(2)]);
        assert!(matches!(r, Value::I64(2)));
        assert!(eval_prim(Prim::Switch, &[Value::I64(1), Value::I64(1), Value::I64(2)]).is_err());
    }

    #[test]
    fn tuple_ops() {
        let t = ev(Prim::MakeTuple, &[Value::I64(1), Value::F64(2.0)]);
        assert!(matches!(ev(Prim::TupleGetItem, &[t.clone(), Value::I64(0)]), Value::I64(1)));
        assert!(matches!(ev(Prim::TupleGetItem, &[t.clone(), Value::I64(-1)]), Value::F64(_)));
        assert!(matches!(ev(Prim::TupleLen, &[t.clone()]), Value::I64(2)));
        assert!(eval_prim(Prim::TupleGetItem, &[t.clone(), Value::I64(5)]).is_err());
        let inj = ev(Prim::TupleInject, &[Value::I64(1), Value::I64(3), Value::F64(7.0)]);
        match inj {
            Value::Tuple(items) => {
                assert!(matches!(items[0], Value::ZeroT));
                assert!(matches!(items[1], Value::F64(v) if v == 7.0));
                assert!(matches!(items[2], Value::ZeroT));
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(ev(Prim::IsNil, &[Value::Unit]), Value::Bool(true)));
        assert!(matches!(ev(Prim::IsNil, &[Value::I64(0)]), Value::Bool(false)));
    }

    #[test]
    fn env_ops_roundtrip() {
        let e = ev(Prim::NewEnv, &[]);
        let k = Value::Key(42);
        let e2 = ev(Prim::EnvSetItem, &[e.clone(), k.clone(), Value::F64(1.5)]);
        assert!(matches!(ev(Prim::EnvGetItem, &[e2.clone(), k.clone()]), Value::F64(v) if v == 1.5));
        // missing key → ZeroT
        assert!(matches!(ev(Prim::EnvGetItem, &[e, k.clone()]), Value::ZeroT));
        // getitem on ZeroT env → ZeroT
        assert!(matches!(ev(Prim::EnvGetItem, &[Value::ZeroT, k]), Value::ZeroT));
    }

    #[test]
    fn gadd_monoid() {
        // identity
        assert!(matches!(gadd(&Value::ZeroT, &Value::F64(3.0)).unwrap(), Value::F64(v) if v == 3.0));
        assert!(matches!(gadd(&Value::F64(3.0), &Value::ZeroT).unwrap(), Value::F64(v) if v == 3.0));
        // tuples
        let a = Value::tuple(vec![Value::F64(1.0), Value::ZeroT]);
        let b = Value::tuple(vec![Value::F64(2.0), Value::F64(5.0)]);
        match gadd(&a, &b).unwrap() {
            Value::Tuple(items) => {
                assert!(matches!(items[0], Value::F64(v) if v == 3.0));
                assert!(matches!(items[1], Value::F64(v) if v == 5.0));
            }
            other => panic!("{other:?}"),
        }
        // envs merge with addition on collision
        let mut m1 = EnvMap::new();
        m1.insert(1, Value::F64(1.0));
        let mut m2 = EnvMap::new();
        m2.insert(1, Value::F64(2.0));
        m2.insert(2, Value::F64(9.0));
        let merged = gadd(&Value::Env(Arc::new(m1)), &Value::Env(Arc::new(m2))).unwrap();
        match merged {
            Value::Env(e) => {
                assert!(matches!(e[&1], Value::F64(v) if v == 3.0));
                assert!(matches!(e[&2], Value::F64(v) if v == 9.0));
            }
            other => panic!("{other:?}"),
        }
        // length mismatch errors
        let c = Value::tuple(vec![Value::F64(0.0)]);
        assert!(gadd(&a, &c).is_err());
    }

    #[test]
    fn zeros_and_ones_like() {
        let t = Value::Tensor(Tensor::from_f64(&[1.0, 2.0]));
        match zeros_like(&t) {
            Value::Tensor(z) => assert_eq!(z.as_f64_vec(), vec![0.0, 0.0]),
            other => panic!("{other:?}"),
        }
        let tup = Value::tuple(vec![Value::F64(5.0), t]);
        match zeros_like(&tup) {
            Value::Tuple(items) => assert!(matches!(items[0], Value::F64(v) if v == 0.0)),
            other => panic!("{other:?}"),
        }
        assert!(matches!(ev(Prim::OnesLike, &[Value::F64(9.0)]), Value::F64(v) if v == 1.0));
    }

    #[test]
    fn tensor_shape_ops() {
        let t = Value::Tensor(Tensor::from_f64(&[1.0, 2.0, 3.0, 4.0]));
        let shape2x2 = Value::tuple(vec![Value::I64(2), Value::I64(2)]);
        let r = ev(Prim::Reshape, &[t.clone(), shape2x2.clone()]);
        assert!(matches!(&r, Value::Tensor(t) if t.shape() == [2, 2]));
        let s = ev(Prim::ShapeOf, &[r.clone()]);
        assert!(s.structural_eq(&shape2x2));
        let mm = ev(Prim::MatMul, &[r.clone(), r]);
        assert!(matches!(&mm, Value::Tensor(t) if t.shape() == [2, 2]));
        assert!(matches!(ev(Prim::ReduceSum, &[t.clone()]), Value::Tensor(s) if s.item().unwrap() == 10.0));
        assert!(matches!(ev(Prim::Item, &[ev(Prim::ReduceMean, &[t])]), Value::F64(v) if v == 2.5));
    }

    #[test]
    fn rng_deterministic_and_split() {
        let shape = Value::tuple(vec![Value::I64(3)]);
        let a = ev(Prim::RngUniform, &[Value::I64(7), shape.clone()]);
        let b = ev(Prim::RngUniform, &[Value::I64(7), shape.clone()]);
        assert!(a.structural_eq(&b), "same seed, same tensor");
        let c = ev(Prim::RngUniform, &[Value::I64(8), shape]);
        assert!(!a.structural_eq(&c));
        let s = ev(Prim::RngSplit, &[Value::I64(7)]);
        match s {
            Value::Tuple(items) => assert!(!items[0].structural_eq(&items[1])),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batching_prims_evaluate() {
        let x = Value::Tensor(
            Tensor::from_f64_shaped(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]).unwrap(),
        );
        // per-example total
        assert!(matches!(
            ev(Prim::SumTail, &[x.clone()]),
            Value::Tensor(t) if t.as_f64_vec() == vec![6.0, 15.0]
        ));
        // bmm: [2,3] per-example vectors @ shared [3,1] matrix
        let w = Value::Tensor(Tensor::from_f64_shaped(vec![1.0, 1.0, 1.0], vec![3, 1]).unwrap());
        let r = ev(
            Prim::BatchMatMul,
            &[x.clone(), w, Value::Bool(true), Value::Bool(false)],
        );
        assert!(matches!(&r, Value::Tensor(t) if t.shape() == [2, 1]));
        // broadcast_lead / sum_to_lead round-trip
        let v = Value::Tensor(Tensor::from_f64(&[2.0, 3.0]));
        let b = ev(Prim::BroadcastLead, &[v.clone(), x.clone()]);
        assert!(matches!(&b, Value::Tensor(t) if t.shape() == [2, 3]));
        let s = ev(Prim::SumToLead, &[b, v]);
        assert!(matches!(&s, Value::Tensor(t) if t.as_f64_vec() == vec![6.0, 9.0]));
        // move_axis + broadcast_batch
        let m = ev(Prim::MoveAxis, &[x.clone(), Value::I64(1), Value::I64(0)]);
        assert!(matches!(&m, Value::Tensor(t) if t.shape() == [3, 2]));
        let bb = ev(Prim::BroadcastBatch, &[Value::F64(1.5), x.clone()]);
        assert!(matches!(&bb, Value::Tensor(t) if t.shape() == [2]));
        // sum_to_tail toward a scalar target
        let st = ev(Prim::SumToTail, &[x.clone(), Value::F64(0.0)]);
        assert!(matches!(&st, Value::Tensor(t) if t.as_f64_vec() == vec![6.0, 15.0]));
        // broadcast_tail undoes it: per-example scalars spread over each
        // example's entries (batch axis pinned).
        let bt = ev(Prim::BroadcastTail, &[st.clone(), x.clone()]);
        assert!(matches!(
            &bt,
            Value::Tensor(t) if t.shape() == [2, 3]
                && t.as_f64_vec() == vec![6.0, 6.0, 6.0, 15.0, 15.0, 15.0]
        ));
        assert!(matches!(ev(Prim::BroadcastTail, &[Value::ZeroT, x.clone()]), Value::ZeroT));
        // ZeroT absorbs
        assert!(matches!(ev(Prim::SumTail, &[Value::ZeroT]), Value::ZeroT));
        assert!(matches!(
            ev(
                Prim::BatchMatMul,
                &[Value::ZeroT, x, Value::Bool(true), Value::Bool(false)]
            ),
            Value::ZeroT
        ));
    }

    #[test]
    fn raise_errors() {
        let e = eval_prim(Prim::Raise, &[Value::str("boom")]).unwrap_err();
        assert!(format!("{e}").contains("boom"));
    }

    #[test]
    fn arity_checked() {
        assert!(eval_prim(Prim::Add, &[Value::I64(1)]).is_err());
        assert!(eval_prim(Prim::Neg, &[]).is_err());
    }
}

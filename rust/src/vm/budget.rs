//! Execution budgets and cooperative cancellation.
//!
//! The IR "naturally supports function calls, higher-order functions and
//! recursion" (paper §3) — which means a served artifact can legally
//! diverge, recurse without bound, or allocate without bound. This module
//! is the governor: a per-invocation [`ExecBudget`] carries four
//! independent ceilings —
//!
//! * **instruction fuel** — a hard cap on bytecode instructions retired;
//! * **call-frame depth** — a cap that *tightens* the VM's own
//!   `max_depth` (it can never loosen it);
//! * **tensor bytes** — a ceiling on tensor bytes produced by primitive
//!   calls during the invocation;
//! * **a wall-clock deadline / cancel flag** — carried as a shared
//!   [`CancelToken`] so the serving layer (or any other owner) can revoke
//!   an in-flight call from outside.
//!
//! Exceeding any ceiling unwinds the interpreter with a structured
//! [`Trap`] error — never a panic, never an OOM. Traps travel as the
//! source of the `anyhow` error chain, so callers at any layer can
//! `downcast_ref::<Trap>()` to distinguish "the program was stopped by
//! policy" from "the program was wrong".
//!
//! Cost discipline: budget checks ride the interpreter's existing
//! per-instruction bookkeeping. Fuel is one branch + decrement; the
//! wall-clock read (`Instant::now`) happens once per
//! [`DEADLINE_CHECK_PERIOD`] instructions, and once per chunk inside
//! fused-kernel loops (`vm::fused` via `pool::for_chunks_mut_cancellable`)
//! where a single chunk is ~16k elements of work. A default budget
//! short-circuits to a single boolean test per instruction.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Instructions between wall-clock deadline probes on the interpreter's
/// hot path. 1024 instructions is microseconds of work — far finer than
/// any deadline a serving layer would set — while keeping `Instant::now`
/// off the per-instruction path.
pub const DEADLINE_CHECK_PERIOD: u64 = 1024;

/// Why an invocation was stopped by its budget. Structured (not a string)
/// so every layer above the VM — fallback isolation, the serve error
/// taxonomy, metrics — can react to *which* ceiling was hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trap {
    /// The instruction-fuel ceiling was exhausted.
    FuelExhausted { limit: u64 },
    /// The call-frame depth cap was reached (the budget's cap or the VM's
    /// own `max_depth`, whichever is tighter).
    DepthExceeded { limit: usize },
    /// The invocation produced more tensor bytes than its ceiling.
    MemExceeded { limit: u64, used: u64 },
    /// The wall-clock deadline on the invocation's [`CancelToken`] passed.
    DeadlineExceeded,
    /// The invocation's [`CancelToken`] was revoked explicitly.
    Cancelled,
}

impl fmt::Display for Trap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Trap::FuelExhausted { limit } => {
                write!(f, "instruction fuel exhausted ({limit} instructions)")
            }
            // Same wording as the VM's historic depth error so existing
            // callers matching on "recursion limit" keep working.
            Trap::DepthExceeded { limit } => {
                write!(f, "recursion limit exceeded ({limit} frames)")
            }
            Trap::MemExceeded { limit, used } => {
                write!(f, "tensor allocation budget exceeded ({used} of {limit} bytes)")
            }
            Trap::DeadlineExceeded => write!(f, "execution deadline exceeded"),
            Trap::Cancelled => write!(f, "execution cancelled"),
        }
    }
}

impl std::error::Error for Trap {}

/// A shared cancellation handle: an explicit revoke flag plus an optional
/// wall-clock deadline, fixed at construction. Clone it freely — all
/// clones observe one flag. The VM polls it on the instruction path (every
/// [`DEADLINE_CHECK_PERIOD`] instructions) and fused chunk loops poll it
/// per chunk, including on intra-op pool worker threads.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    inner: Arc<TokenInner>,
}

#[derive(Debug, Default)]
struct TokenInner {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token with no deadline; fires only via [`CancelToken::cancel`].
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// A token that fires when `deadline` passes (or on explicit cancel).
    pub fn with_deadline(deadline: Instant) -> CancelToken {
        CancelToken {
            inner: Arc::new(TokenInner { cancelled: AtomicBool::new(false), deadline: Some(deadline) }),
        }
    }

    /// Convenience: a deadline `timeout` from now (saturating).
    pub fn with_timeout(timeout: Duration) -> CancelToken {
        let deadline =
            Instant::now().checked_add(timeout).unwrap_or_else(|| Instant::now() + Duration::from_secs(3600));
        CancelToken::with_deadline(deadline)
    }

    /// The wall-clock deadline, when one was set.
    pub fn deadline(&self) -> Option<Instant> {
        self.inner.deadline
    }

    /// Revoke: every holder's next check observes [`Trap::Cancelled`].
    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::Relaxed);
    }

    /// Has the token been explicitly revoked? (Flag only — does not read
    /// the clock.)
    pub fn is_cancelled(&self) -> bool {
        self.inner.cancelled.load(Ordering::Relaxed)
    }

    /// Should a cooperative loop stop now? Flag check plus (when a
    /// deadline exists) one clock read.
    pub fn should_stop(&self) -> bool {
        self.is_cancelled() || self.inner.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Full check, as a structured error: explicit revocation wins over
    /// deadline expiry so a `cancel()` is always reported as such.
    pub fn check(&self) -> Result<(), Trap> {
        if self.is_cancelled() {
            return Err(Trap::Cancelled);
        }
        if self.inner.deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(Trap::DeadlineExceeded);
        }
        Ok(())
    }
}

/// Per-invocation resource ceilings. `Default` is unlimited in every
/// dimension; each `with_*` tightens one of them. Cheap to clone — the
/// only non-scalar member is the token's `Arc`.
#[derive(Clone, Debug, Default)]
pub struct ExecBudget {
    /// Maximum bytecode instructions this invocation may retire.
    pub fuel: Option<u64>,
    /// Call-frame depth cap. Applied as `min` with the VM's own
    /// `max_depth` — a budget can only tighten the recursion limit.
    pub max_depth: Option<usize>,
    /// Ceiling on tensor bytes produced by primitive calls.
    pub max_tensor_bytes: Option<u64>,
    /// Shared deadline / cancellation handle.
    pub token: Option<CancelToken>,
}

impl ExecBudget {
    /// The unlimited budget (same as `Default`).
    pub fn unlimited() -> ExecBudget {
        ExecBudget::default()
    }

    pub fn with_fuel(mut self, fuel: u64) -> ExecBudget {
        self.fuel = Some(fuel);
        self
    }

    pub fn with_max_depth(mut self, depth: usize) -> ExecBudget {
        self.max_depth = Some(depth);
        self
    }

    pub fn with_max_tensor_bytes(mut self, bytes: u64) -> ExecBudget {
        self.max_tensor_bytes = Some(bytes);
        self
    }

    pub fn with_token(mut self, token: CancelToken) -> ExecBudget {
        self.token = Some(token);
        self
    }

    /// Attach a fresh token expiring at `deadline`.
    pub fn with_deadline(self, deadline: Instant) -> ExecBudget {
        self.with_token(CancelToken::with_deadline(deadline))
    }

    /// True when no ceiling is set at all (the common case, which the
    /// meter fast-paths).
    pub fn is_unlimited(&self) -> bool {
        self.fuel.is_none()
            && self.max_depth.is_none()
            && self.max_tensor_bytes.is_none()
            && self.token.is_none()
    }
}

/// The per-invocation checking state compiled from an [`ExecBudget`]: a
/// local fuel countdown, the effective depth cap, a byte accumulator, and
/// the deadline probe countdown. Lives on the interpreter's stack frame —
/// no atomics, no sharing.
pub(crate) struct BudgetMeter {
    active: bool,
    fuel_limit: u64,
    fuel_left: u64,
    depth_cap: usize,
    bytes_cap: u64,
    bytes_used: u64,
    token: Option<CancelToken>,
    probe_countdown: u64,
}

impl BudgetMeter {
    pub(crate) fn new(budget: &ExecBudget, vm_max_depth: usize) -> BudgetMeter {
        let fuel = budget.fuel.unwrap_or(u64::MAX);
        BudgetMeter {
            active: !budget.is_unlimited(),
            fuel_limit: fuel,
            fuel_left: fuel,
            depth_cap: budget.max_depth.map_or(vm_max_depth, |d| d.min(vm_max_depth)),
            bytes_cap: budget.max_tensor_bytes.unwrap_or(u64::MAX),
            bytes_used: 0,
            token: budget.token.clone(),
            probe_countdown: DEADLINE_CHECK_PERIOD,
        }
    }

    /// Per-instruction check: fuel, and a periodic token probe. One
    /// branch when the budget is unlimited.
    #[inline(always)]
    pub(crate) fn step(&mut self) -> Result<(), Trap> {
        if !self.active {
            return Ok(());
        }
        if self.fuel_left == 0 {
            return Err(Trap::FuelExhausted { limit: self.fuel_limit });
        }
        self.fuel_left -= 1;
        self.probe_countdown -= 1;
        if self.probe_countdown == 0 {
            self.probe_countdown = DEADLINE_CHECK_PERIOD;
            if let Some(t) = &self.token {
                t.check()?;
            }
        }
        Ok(())
    }

    /// Depth check at frame push (replaces the VM's inline `max_depth`
    /// test; the budget can only have tightened the cap).
    #[inline(always)]
    pub(crate) fn check_depth(&self, frames: usize) -> Result<(), Trap> {
        if frames >= self.depth_cap {
            return Err(Trap::DepthExceeded { limit: self.depth_cap });
        }
        Ok(())
    }

    /// Account tensor bytes a primitive call just produced. Free when no
    /// byte ceiling is set.
    #[inline(always)]
    pub(crate) fn charge(&mut self, v: &crate::vm::value::Value) -> Result<(), Trap> {
        if self.bytes_cap == u64::MAX {
            return Ok(());
        }
        self.bytes_used = self.bytes_used.saturating_add(value_bytes(v));
        if self.bytes_used > self.bytes_cap {
            return Err(Trap::MemExceeded { limit: self.bytes_cap, used: self.bytes_used });
        }
        Ok(())
    }

    /// The token to thread into fused chunk loops (pool workers poll it).
    pub(crate) fn token(&self) -> Option<&CancelToken> {
        self.token.as_ref()
    }
}

/// Tensor bytes referenced by a value: tensors report their buffer size,
/// tuples sum their members, everything else is free.
pub(crate) fn value_bytes(v: &crate::vm::value::Value) -> u64 {
    use crate::vm::value::Value;
    match v {
        Value::Tensor(t) => t.nbytes() as u64,
        Value::Tuple(items) => items.iter().map(value_bytes).sum(),
        _ => 0,
    }
}

/// Cumulative trap telemetry, in the idiom of `vm::plan::PlanStats`:
/// never reset, safe to read from any thread, surfaced through
/// `Executable::trap_stats` and the serve metrics snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrapStats {
    /// Invocations stopped by the instruction-fuel ceiling.
    pub fuel_exhausted: u64,
    /// Invocations stopped by the call-frame depth cap.
    pub depth_trapped: u64,
    /// Invocations stopped by the tensor-bytes ceiling.
    pub mem_trapped: u64,
    /// Invocations stopped by a deadline or explicit cancellation.
    pub deadline_exceeded: u64,
}

impl TrapStats {
    pub fn total(&self) -> u64 {
        self.fuel_exhausted + self.depth_trapped + self.mem_trapped + self.deadline_exceeded
    }

    /// Component-wise sum (for aggregating over several executables).
    pub fn plus(&self, o: &TrapStats) -> TrapStats {
        TrapStats {
            fuel_exhausted: self.fuel_exhausted + o.fuel_exhausted,
            depth_trapped: self.depth_trapped + o.depth_trapped,
            mem_trapped: self.mem_trapped + o.mem_trapped,
            deadline_exceeded: self.deadline_exceeded + o.deadline_exceeded,
        }
    }
}

/// Lock-free cumulative trap accumulator owned by a `Vm` (relaxed atomics:
/// monotone telemetry, not synchronization).
#[derive(Debug, Default)]
pub(crate) struct TrapCell {
    fuel_exhausted: AtomicU64,
    depth_trapped: AtomicU64,
    mem_trapped: AtomicU64,
    deadline_exceeded: AtomicU64,
}

impl TrapCell {
    pub(crate) fn record(&self, t: &Trap) {
        let c = match t {
            Trap::FuelExhausted { .. } => &self.fuel_exhausted,
            Trap::DepthExceeded { .. } => &self.depth_trapped,
            Trap::MemExceeded { .. } => &self.mem_trapped,
            Trap::DeadlineExceeded | Trap::Cancelled => &self.deadline_exceeded,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn stats(&self) -> TrapStats {
        TrapStats {
            fuel_exhausted: self.fuel_exhausted.load(Ordering::Relaxed),
            depth_trapped: self.depth_trapped.load(Ordering::Relaxed),
            mem_trapped: self.mem_trapped.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_budget_is_unlimited_and_meter_is_inert() {
        let b = ExecBudget::default();
        assert!(b.is_unlimited());
        let mut m = BudgetMeter::new(&b, 100);
        for _ in 0..10_000 {
            m.step().unwrap();
        }
        m.check_depth(99).unwrap();
        assert!(m.check_depth(100).is_err(), "the VM's own cap still applies");
    }

    #[test]
    fn fuel_runs_out_exactly() {
        let b = ExecBudget::default().with_fuel(3);
        let mut m = BudgetMeter::new(&b, 100);
        m.step().unwrap();
        m.step().unwrap();
        m.step().unwrap();
        match m.step() {
            Err(Trap::FuelExhausted { limit: 3 }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn budget_depth_only_tightens() {
        let tight = BudgetMeter::new(&ExecBudget::default().with_max_depth(5), 100);
        assert!(tight.check_depth(5).is_err());
        let loose = BudgetMeter::new(&ExecBudget::default().with_max_depth(500), 100);
        assert!(loose.check_depth(100).is_err(), "vm cap wins when tighter");
    }

    #[test]
    fn cancel_and_deadline_fire() {
        let t = CancelToken::new();
        assert!(t.check().is_ok());
        t.cancel();
        assert_eq!(t.check(), Err(Trap::Cancelled));
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_millis(1));
        assert_eq!(t.check(), Err(Trap::DeadlineExceeded));
        assert!(t.should_stop());
        // Explicit revocation outranks deadline expiry in the report.
        t.cancel();
        assert_eq!(t.check(), Err(Trap::Cancelled));
    }

    #[test]
    fn byte_charging_trips_the_ceiling() {
        use crate::tensor::Tensor;
        use crate::vm::value::Value;
        let v = Value::Tensor(Tensor::from_f64(&[0.0; 4])); // 32 bytes
        assert_eq!(value_bytes(&v), 32);
        let tup = Value::tuple(vec![v.clone(), v.clone()]);
        assert_eq!(value_bytes(&tup), 64);
        let mut m = BudgetMeter::new(&ExecBudget::default().with_max_tensor_bytes(40), 100);
        m.charge(&v).unwrap();
        match m.charge(&v) {
            Err(Trap::MemExceeded { limit: 40, used: 64 }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn trap_cell_accumulates_by_kind() {
        let c = TrapCell::default();
        c.record(&Trap::FuelExhausted { limit: 1 });
        c.record(&Trap::DeadlineExceeded);
        c.record(&Trap::Cancelled);
        let s = c.stats();
        assert_eq!(s.fuel_exhausted, 1);
        assert_eq!(s.deadline_exceeded, 2);
        assert_eq!(s.total(), 3);
        assert_eq!(s.plus(&s).total(), 6);
    }

    #[test]
    fn trap_messages_are_stable() {
        assert!(Trap::DepthExceeded { limit: 7 }.to_string().contains("recursion limit"));
        assert!(Trap::FuelExhausted { limit: 9 }.to_string().contains("fuel"));
        assert!(Trap::DeadlineExceeded.to_string().contains("deadline"));
        assert!(Trap::MemExceeded { limit: 1, used: 2 }.to_string().contains("budget"));
    }
}

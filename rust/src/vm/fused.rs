//! Execution of `Prim::FusedMap`: one loop, no intermediate tensors.
//!
//! A fused region evaluates its postfix [`FusedExpr`] once per output
//! element on a small value stack, monomorphized per element type (f32 and
//! f64), so the `as_f64_vec` round-trip and the per-op output allocations of
//! unfused execution disappear. Because the IR is shape-erased, legality
//! beyond purity is decided *here*, against the concrete arguments:
//!
//! 1. a **shape simulation** replays NumPy broadcasting over the postfix
//!    program to find the output shape (and rejects exactly what the
//!    unfused chain would have rejected);
//! 2. a **dtype simulation** replays the typed kernels' promotion rules; the
//!    fast path fires only when every compute step lands on one float type;
//! 3. anything else — symbolic zeros, scalar-only chains, integer or mixed
//!    intermediates, shape errors — falls back to a step-by-step **replay**
//!    through the ordinary [`eval_prim`], which is bit-for-bit the unfused
//!    semantics by construction.
//!
//! The output buffer is stolen from a dying same-shape/same-dtype leaf when
//! one is uniquely owned (the caller moves dying registers into `args`, so
//! Arc uniqueness is an exact aliasing guard).
//!
//! Large index spaces run data-parallel on the shared intra-op pool
//! ([`super::pool`]): the output is split into fixed-size contiguous chunks
//! (boundaries derive from the element count alone, never the thread
//! count), each task writes a disjoint `&mut` slice, and element `k` reads
//! only leaf index `k` — including the stolen-for-output leaf, whose chunk
//! partition coincides with the output's because stealing requires shape
//! equality. Results are therefore bit-identical to the sequential loop at
//! every pool size.
//!
//! ## Trailing reductions
//!
//! A fused program may carry a [`FusedReduce`]: the map values are then
//! consumed by an inline `sum` / `sum_tail` / `sum_axis` instead of being
//! materialized. Each output cell accumulates its map elements in f64, in
//! the exact index order of the standalone reduction kernels
//! (`tensor/ops.rs`), and narrows once at the end — so a fused reduction is
//! bit-identical to map-then-reduce. Parallelism splits *output cells*
//! only; a single cell's accumulation is never divided, which keeps the
//! result independent of the pool size.
//!
//! ## Shape-specialized plans
//!
//! When the `CallPrim` site carries a plan slot (see `vm::plan`), the
//! simulation and the O(numel) broadcast index maps run once per leaf-shape
//! key; later calls with the same shapes dispatch straight into the typed
//! loop with the cached geometry (or straight to replay, when simulation
//! declined for those shapes).

use super::budget::CancelToken;
use super::plan::{
    fused_leaf_keys, fused_leaf_match, FusedPlan, KernelPlan, LeafAccess, PlanCache, Site,
    TypedFused,
};
use super::pool;
use super::prims::eval_prim_inplace;
use super::value::Value;
use crate::ir::{FusedExpr, FusedOp, FusedReduce, Prim, MAX_FUSED_STACK};
use crate::tensor::ops::{broadcast_shapes, promote, unary_out_dtype, Elem, NumOp, Rd, UnOp};
use crate::tensor::{DType, Tensor};
use crate::vm::exec::ExecStats;
use anyhow::{anyhow, bail, Result};
use std::borrow::Cow;
use std::sync::Arc;

/// Map a binary arithmetic primitive onto its typed kernel op. (FloorDiv
/// and Mod have typed kernels for the in-place path but are not in the
/// fusion pass's eligible set — `simulate` never sees them.)
pub fn num_op_of(p: Prim) -> Option<NumOp> {
    Some(match p {
        Prim::Add => NumOp::Add,
        Prim::Sub => NumOp::Sub,
        Prim::Mul => NumOp::Mul,
        Prim::Div => NumOp::Div,
        Prim::Pow => NumOp::Pow,
        Prim::Maximum => NumOp::Maximum,
        Prim::Minimum => NumOp::Minimum,
        Prim::FloorDiv => NumOp::FloorDiv,
        Prim::Mod => NumOp::Mod,
        _ => return None,
    })
}

/// Map a fusable unary primitive onto its typed kernel op.
pub fn un_op_of(p: Prim) -> Option<UnOp> {
    Some(match p {
        Prim::Neg => UnOp::Neg,
        Prim::Exp => UnOp::Exp,
        Prim::Ln => UnOp::Ln,
        Prim::Tanh => UnOp::Tanh,
        Prim::Sqrt => UnOp::Sqrt,
        Prim::Sin => UnOp::Sin,
        Prim::Cos => UnOp::Cos,
        Prim::Relu => UnOp::Relu,
        Prim::Sigmoid => UnOp::Sigmoid,
        Prim::Abs => UnOp::Abs,
        Prim::Sign => UnOp::Sign,
        Prim::Step => UnOp::Step,
        _ => return None,
    })
}

/// Evaluate a `fused_map` application. `args[0]` must be the
/// [`Value::Fused`] program; `args[1..]` are the leaves, which the VM's hot
/// path has already *moved* out of dying registers (so uniquely-owned
/// buffers really are dead and reusable). Returns the result plus the
/// number of tensor allocations avoided relative to unfused execution.
///
/// This is the generic (plan-less) entry point; the VM's `CallPrim` path
/// goes through [`eval_fused_at`] so repeat shapes skip the simulation.
pub fn eval_fused(args: &mut [Value]) -> Result<(Value, u64)> {
    let mut sink = ExecStats::default();
    eval_fused_at(args, None, &mut sink, None)
}

/// Evaluate a `fused_map` application, consulting (and feeding) the shape
/// specialization tier when the call site has a plan slot. `site` is `None`
/// on plan-less paths (tier disabled, first-class prim call, generic
/// wrapper); the result is identical either way — plans change where shape
/// work happens, never what is computed.
pub(crate) fn eval_fused_at(
    args: &mut [Value],
    site: Option<(&PlanCache, &Site)>,
    stats: &mut ExecStats,
    token: Option<&CancelToken>,
) -> Result<(Value, u64)> {
    let expr = match &args[0] {
        Value::Fused(e) => e.clone(),
        other => bail!("fused_map expects a fused program, got {}", other.type_name()),
    };
    let leaves = &mut args[1..];
    if leaves.len() != expr.n_inputs {
        bail!("fused_map expects {} inputs, got {}", expr.n_inputs, leaves.len());
    }

    // Classification: the fast path needs numeric leaves and at least one
    // tensor (a scalar-only chain must return a scalar Value, with integer
    // semantics the loop cannot reproduce — replay handles it). Non-numeric
    // leaves (symbolic zeros, tuples) are unkeyable and bypass the plan
    // tier entirely — this is deliberate and value-kind-based; rank-0 and
    // batch-of-1 *tensors* never bypass.
    let numericish = |v: &Value| {
        matches!(v, Value::Tensor(_) | Value::F64(_) | Value::I64(_) | Value::Bool(_))
    };
    if !leaves.iter().all(numericish) || !leaves.iter().any(|v| matches!(v, Value::Tensor(_))) {
        return Ok((replay(&expr, leaves)?, 0));
    }

    if let Some((cache, s)) = site {
        // Hit path: compare stored keys directly against the live leaves —
        // no key is allocated on a hit.
        if let Some(plan) = s.find(|k| fused_leaf_match(k, leaves)) {
            stats.plan_hits += 1;
            cache.note_hit();
            match plan {
                KernelPlan::Fused(FusedPlan::Typed(tp)) => {
                    return match tp.dtype {
                        DType::F64 => {
                            run_typed::<f64>(&expr, leaves, tp.map_shape.to_vec(), Some(tp), token)
                        }
                        _ => {
                            run_typed::<f32>(&expr, leaves, tp.map_shape.to_vec(), Some(tp), token)
                        }
                    };
                }
                KernelPlan::Fused(FusedPlan::Replay) => return Ok((replay(&expr, leaves)?, 0)),
                // A foreign plan kind at a fused site (impossible today):
                // fall through to the generic flow below.
                _ => {}
            }
        } else {
            let had_plans = s.has_plans();
            if let Some(key) = fused_leaf_keys(leaves) {
                let (plan, result) = match simulate(&expr, leaves) {
                    Some((map_shape, dt @ (DType::F64 | DType::F32))) => {
                        let tp = Arc::new(TypedFused {
                            dtype: dt,
                            map_shape: map_shape.clone().into_boxed_slice(),
                            access: super::plan::build_access(leaves, &map_shape),
                        });
                        let r = match dt {
                            DType::F64 => {
                                run_typed::<f64>(&expr, leaves, map_shape, Some(&tp), token)?
                            }
                            _ => run_typed::<f32>(&expr, leaves, map_shape, Some(&tp), token)?,
                        };
                        (KernelPlan::Fused(FusedPlan::Typed(tp)), r)
                    }
                    _ => (KernelPlan::Fused(FusedPlan::Replay), (replay(&expr, leaves)?, 0)),
                };
                if s.insert(key, plan) {
                    stats.plans_compiled += 1;
                    cache.note_compiled();
                    if had_plans {
                        stats.plan_shape_misses += 1;
                        cache.note_shape_miss();
                    }
                } else {
                    stats.plan_shape_misses += 1;
                    cache.note_shape_miss();
                }
                return Ok(result);
            }
        }
    }

    match simulate(&expr, leaves) {
        Some((out_shape, DType::F64)) => run_typed::<f64>(&expr, leaves, out_shape, None, token),
        Some((out_shape, DType::F32)) => run_typed::<f32>(&expr, leaves, out_shape, None, token),
        _ => Ok((replay(&expr, leaves)?, 0)),
    }
}

/// Joint shape/dtype simulation mirroring the typed kernels in
/// `tensor/ops.rs`. Returns the output (shape, dtype) when every compute
/// step succeeds and lands on a single float dtype; `None` sends the call
/// to the replay path (which reproduces the unfused behavior, including
/// any error, exactly).
fn simulate(expr: &FusedExpr, leaves: &[Value]) -> Option<(Vec<usize>, DType)> {
    let leaf_meta: Vec<(Vec<usize>, DType)> = leaves
        .iter()
        .map(|v| match v {
            Value::Tensor(t) => (t.shape().to_vec(), t.dtype()),
            Value::F64(_) => (Vec::new(), DType::F64),
            Value::I64(_) => (Vec::new(), DType::I64),
            Value::Bool(_) => (Vec::new(), DType::Bool),
            _ => unreachable!("classified above"),
        })
        .collect();

    // Every compute step must produce the same single float dtype.
    fn note(dt: DType, target: &mut Option<DType>) -> Option<()> {
        if !matches!(dt, DType::F32 | DType::F64) {
            return None;
        }
        match target {
            None => *target = Some(dt),
            Some(t) if *t == dt => {}
            Some(_) => return None,
        }
        Some(())
    }

    let mut stack: Vec<(Vec<usize>, DType)> = Vec::with_capacity(expr.max_stack);
    let mut target: Option<DType> = None;
    for op in &expr.ops {
        match op {
            FusedOp::Input(i) => stack.push(leaf_meta[*i as usize].clone()),
            FusedOp::ConstF64(_) => stack.push((Vec::new(), DType::F64)),
            FusedOp::ConstI64(_) => stack.push((Vec::new(), DType::I64)),
            FusedOp::Un(p) => {
                let (s, dt) = stack.pop()?;
                let out = unary_out_dtype(un_op_of(*p)?, dt);
                note(out, &mut target)?;
                stack.push((s, out));
            }
            FusedOp::Bin(p) => {
                num_op_of(*p)?;
                let (sb, db) = stack.pop()?;
                let (sa, da) = stack.pop()?;
                let s = broadcast_shapes(&sa, &sb).ok()?;
                let out = promote(da, db);
                note(out, &mut target)?;
                stack.push((s, out));
            }
            FusedOp::Where => {
                let (sb, db) = stack.pop()?;
                let (sa, da) = stack.pop()?;
                let (sc, dc) = stack.pop()?;
                let ab = broadcast_shapes(&sa, &sb).ok()?;
                let s = broadcast_shapes(&sc, &ab).ok()?;
                let out = promote(da, db);
                // The loop reads the condition in T, but the unfused kernel
                // decides truthiness in f64: those agree only when the
                // condition is boolean, already in T, or T is f64 itself
                // (widening is exact). Anything else (e.g. an f64 condition
                // in an f32 loop, where subnormals would flush to 0) must
                // take the replay path.
                if !(dc == DType::Bool || dc == out || out == DType::F64) {
                    return None;
                }
                note(out, &mut target)?;
                stack.push((s, out));
            }
            FusedOp::BroadcastTo(shape) => {
                let (s, dt) = stack.pop()?;
                // broadcast_to requires the target to dominate the operand.
                let joint = broadcast_shapes(&s, shape).ok()?;
                if &joint != shape {
                    return None;
                }
                note(dt, &mut target)?;
                stack.push((shape.clone(), dt));
            }
        }
    }
    let (shape, dt) = stack.pop()?;
    if Some(dt) != target {
        return None;
    }
    // A trailing axis reduction must be in range for the map shape; out of
    // range declines so replay reproduces the kernel's error verbatim.
    if let Some(FusedReduce::SumAxis(ax)) = &expr.reduce {
        if *ax >= shape.len() {
            return None;
        }
    }
    Some((shape, dt))
}

/// One leaf of the monomorphized loop: tensor leaves go through the same
/// broadcast reader the unfused typed kernels use ([`Rd`] — borrowed when
/// the dtype matches, converted/index-mapped otherwise); scalar `Value`s
/// splat; the stolen-for-output leaf reads the current value of output
/// cell `k` (`cur` — passed in by the loop before it overwrites the cell,
/// so chunked tasks only ever touch their own slice).
enum Leaf<'a, T: Elem> {
    Rd(Rd<'a, T>),
    Splat(T),
    FromOut,
}

impl<'a, T: Elem> Leaf<'a, T> {
    fn new(v: &'a Value, out_shape: &[usize]) -> Leaf<'a, T> {
        match v {
            Value::Tensor(t) => Leaf::Rd(Rd::new(t, out_shape)),
            Value::F64(x) => Leaf::Splat(T::from_f64(*x)),
            Value::I64(x) => Leaf::Splat(T::from_f64(*x as f64)),
            Value::Bool(b) => Leaf::Splat(T::from_f64(if *b { 1.0 } else { 0.0 })),
            _ => unreachable!("classified before dispatch"),
        }
    }

    /// Like [`Leaf::new`] but with a cached [`LeafAccess`] from a kernel
    /// plan: the broadcast decision (and the O(numel) index map) comes
    /// from the plan instead of being re-derived. Any mismatch between the
    /// plan and the live value falls back to the unplanned constructor —
    /// the plan is an accelerator, never an authority over correctness.
    fn with_plan(v: &'a Value, out_shape: &[usize], acc: Option<&'a LeafAccess>) -> Leaf<'a, T> {
        match (acc, v) {
            (Some(LeafAccess::Direct), Value::Tensor(t)) if t.shape() == out_shape => {
                Leaf::Rd(Rd::Slice(T::read(t)))
            }
            (Some(LeafAccess::TensorSplat), Value::Tensor(t)) if t.numel() == 1 => {
                Leaf::Rd(Rd::Splat(T::read(t)[0]))
            }
            (Some(LeafAccess::Mapped(map)), Value::Tensor(t)) => {
                Leaf::Rd(Rd::Mapped(T::read(t), Cow::Borrowed(&map[..])))
            }
            _ => Leaf::new(v, out_shape),
        }
    }

    #[inline]
    fn get(&self, cur: T, k: usize) -> T {
        match self {
            Leaf::Rd(r) => r.get(k),
            Leaf::Splat(v) => *v,
            Leaf::FromOut => cur,
        }
    }
}

/// Execute the typed fast path: the fused map loop, then any trailing
/// reduction. `map_shape` is the pre-reduction index space (what
/// [`simulate`] returned, or what the plan cached); `plan` supplies cached
/// per-leaf access when the call came through the specialization tier.
fn run_typed<T: Elem + Send + Sync>(
    expr: &FusedExpr,
    leaves: &mut [Value],
    map_shape: Vec<usize>,
    plan: Option<&TypedFused>,
    token: Option<&CancelToken>,
) -> Result<(Value, u64)> {
    match expr.reduce {
        None => run_map::<T>(expr, leaves, map_shape, plan, token),
        // `sum_tail` on rank ≤ 1 is the identity (matches `ops::sum_tail`):
        // run the plain map loop.
        Some(FusedReduce::SumTail) if map_shape.len() <= 1 => {
            run_map::<T>(expr, leaves, map_shape, plan, token)
        }
        Some(r) => run_reduced::<T>(expr, leaves, map_shape, plan, r, token),
    }
}

fn run_map<T: Elem + Send + Sync>(
    expr: &FusedExpr,
    leaves: &mut [Value],
    out_shape: Vec<usize>,
    plan: Option<&TypedFused>,
    token: Option<&CancelToken>,
) -> Result<(Value, u64)> {
    let numel: usize = out_shape.iter().product();

    // Output buffer: steal a dying same-shape/same-dtype tensor leaf. The
    // caller moved dying registers into `leaves`, so Arc uniqueness here
    // proves no other reference exists anywhere.
    let mut reused: Option<usize> = None;
    let mut out: Vec<T> = Vec::new();
    for (i, slot) in leaves.iter_mut().enumerate() {
        let candidate = matches!(
            slot,
            Value::Tensor(t) if t.shape() == out_shape.as_slice() && t.dtype() == T::DTYPE
        );
        if !candidate {
            continue;
        }
        let taken = std::mem::replace(slot, Value::Unit);
        let Value::Tensor(t) = taken else { unreachable!() };
        match t.into_unique_buffer() {
            Ok(buf) => {
                out = T::from_buffer(buf).expect("dtype checked");
                reused = Some(i);
                crate::tensor::note_buffer_reuse();
                break;
            }
            Err(shared) => *slot = Value::Tensor(shared),
        }
    }
    if reused.is_none() {
        out = vec![T::zero(); numel];
    }

    let accessors: Vec<Leaf<T>> = leaves
        .iter()
        .enumerate()
        .map(|(i, v)| {
            if reused == Some(i) {
                Leaf::FromOut
            } else {
                Leaf::with_plan(v, &out_shape, plan.map(|p| &p.access[i]))
            }
        })
        .collect();

    // The per-chunk body: identical to the sequential loop over `0..numel`
    // restricted to `[base, base + piece.len())`. Each output cell is read
    // (the stolen leaf's `cur`) and written exactly once, by exactly one
    // task, so chunked execution is bit-identical to sequential.
    let exec_chunk = |piece: &mut [T], base: usize| {
        let mut stack = [T::zero(); MAX_FUSED_STACK];
        for (j, cell) in piece.iter_mut().enumerate() {
            let k = base + j;
            let cur = *cell;
            let mut sp = 0usize;
            for op in &expr.ops {
                match op {
                    FusedOp::Input(i) => {
                        stack[sp] = accessors[*i as usize].get(cur, k);
                        sp += 1;
                    }
                    FusedOp::ConstF64(v) => {
                        stack[sp] = T::from_f64(*v);
                        sp += 1;
                    }
                    FusedOp::ConstI64(v) => {
                        stack[sp] = T::from_f64(*v as f64);
                        sp += 1;
                    }
                    FusedOp::Un(p) => {
                        let op = un_op_of(*p).expect("validated by simulate");
                        stack[sp - 1] = T::un(op, stack[sp - 1]);
                    }
                    FusedOp::Bin(p) => {
                        let op = num_op_of(*p).expect("validated by simulate");
                        sp -= 1;
                        stack[sp - 1] = T::bin(op, stack[sp - 1], stack[sp]);
                    }
                    FusedOp::Where => {
                        sp -= 2;
                        let c = stack[sp - 1];
                        stack[sp - 1] = if c.is_truthy() { stack[sp] } else { stack[sp + 1] };
                    }
                    FusedOp::BroadcastTo(_) => {} // shape-only; value unchanged
                }
            }
            *cell = stack[0];
        }
    };
    if numel < pool::FUSED_PAR_MIN_ELEMS {
        // Small spaces finish in microseconds; a budget check here would
        // cost more than the loop.
        exec_chunk(&mut out, 0);
    } else {
        pool::for_chunks_mut_cancellable(&mut out, pool::FUSED_CHUNK_ELEMS, token, exec_chunk)?;
    }

    let saved = expr.interior_allocs() + u64::from(reused.is_some());
    let t = Tensor::new(out_shape, T::buffer(out)).map_err(|e| anyhow!("{e}"))?;
    Ok((Value::Tensor(t), saved))
}

/// Execute the fused map with a trailing reduction: map values are
/// consumed by per-output-cell f64 accumulation in the exact index order
/// of the standalone kernels (`reduce_sum_all` / `sum_tail` /
/// `reduce_axis` in `tensor/ops.rs`), narrowing once per cell — so the
/// result is bit-identical to map-then-reduce at every pool size. No
/// output-buffer steal happens here (the output is smaller than the map
/// space); `saved` is [`FusedExpr::interior_allocs`], which for reduced
/// programs already counts the never-materialized map tensor.
fn run_reduced<T: Elem + Send + Sync>(
    expr: &FusedExpr,
    leaves: &mut [Value],
    map_shape: Vec<usize>,
    plan: Option<&TypedFused>,
    reduce: FusedReduce,
    token: Option<&CancelToken>,
) -> Result<(Value, u64)> {
    let map_numel: usize = map_shape.iter().product();
    let accessors: Vec<Leaf<T>> = leaves
        .iter()
        .enumerate()
        .map(|(i, v)| Leaf::with_plan(v, &map_shape, plan.map(|p| &p.access[i])))
        .collect();

    // Evaluate the postfix program at map index `k`. No leaf is stolen
    // for the output here, so no accessor is `FromOut` and `cur` is inert.
    let eval_at = |k: usize| -> T {
        let mut stack = [T::zero(); MAX_FUSED_STACK];
        let mut sp = 0usize;
        for op in &expr.ops {
            match op {
                FusedOp::Input(i) => {
                    stack[sp] = accessors[*i as usize].get(T::zero(), k);
                    sp += 1;
                }
                FusedOp::ConstF64(v) => {
                    stack[sp] = T::from_f64(*v);
                    sp += 1;
                }
                FusedOp::ConstI64(v) => {
                    stack[sp] = T::from_f64(*v as f64);
                    sp += 1;
                }
                FusedOp::Un(p) => {
                    let op = un_op_of(*p).expect("validated by simulate");
                    stack[sp - 1] = T::un(op, stack[sp - 1]);
                }
                FusedOp::Bin(p) => {
                    let op = num_op_of(*p).expect("validated by simulate");
                    sp -= 1;
                    stack[sp - 1] = T::bin(op, stack[sp - 1], stack[sp]);
                }
                FusedOp::Where => {
                    sp -= 2;
                    let c = stack[sp - 1];
                    stack[sp - 1] = if c.is_truthy() { stack[sp] } else { stack[sp + 1] };
                }
                FusedOp::BroadcastTo(_) => {} // shape-only; value unchanged
            }
        }
        stack[0]
    };

    // Chunked fill over *output cells*: one cell's accumulation is never
    // split, and chunk boundaries derive from shape alone (cells per chunk
    // scaled down by the reduction length so a chunk stays ~the same work
    // as an elementwise chunk), so results are identical at any pool size.
    let fill = |out: &mut [T],
                red_len: usize,
                cell: &(dyn Fn(usize) -> f64 + Sync)|
     -> Result<(), super::budget::Trap> {
        let body = |piece: &mut [T], base: usize| {
            for (j, o) in piece.iter_mut().enumerate() {
                *o = T::from_f64(cell(base + j));
            }
        };
        if map_numel < pool::FUSED_PAR_MIN_ELEMS || out.len() < 2 {
            body(out, 0);
            Ok(())
        } else {
            let chunk = (pool::FUSED_CHUNK_ELEMS / red_len.max(1)).max(1);
            pool::for_chunks_mut_cancellable(out, chunk, token, body)
        }
    };

    let saved = expr.interior_allocs();
    let t = match reduce {
        FusedReduce::Sum => {
            // Strictly sequential, ascending k — `reduce_sum_all`'s order.
            // Long accumulations still honor the token, checking once per
            // chunk-sized stretch.
            let mut acc = 0.0f64;
            for k in 0..map_numel {
                if k % pool::FUSED_CHUNK_ELEMS == 0 {
                    if let Some(tok) = token {
                        tok.check()?;
                    }
                }
                acc += eval_at(k).to_f64();
            }
            Tensor::new(Vec::new(), T::buffer(vec![T::from_f64(acc)]))
        }
        FusedReduce::SumTail => {
            // rank ≥ 2 here (rank ≤ 1 ran the identity map path).
            let b = map_shape[0];
            let inner = map_numel / b.max(1);
            let mut out = vec![T::zero(); b];
            fill(&mut out, inner, &|o| {
                let mut acc = 0.0f64;
                for i in 0..inner {
                    acc += eval_at(o * inner + i).to_f64();
                }
                acc
            })?;
            Tensor::new(vec![b], T::buffer(out))
        }
        FusedReduce::SumAxis(ax) => {
            // In range by `simulate`'s check; decomposition and per-cell
            // ascending-k order mirror `ops::reduce_axis` exactly.
            let n_r = map_shape[ax];
            let outer: usize = map_shape[..ax].iter().product();
            let inner: usize = map_shape[ax + 1..].iter().product();
            let mut out_shape = map_shape.clone();
            out_shape.remove(ax);
            let mut out = vec![T::zero(); outer * inner];
            fill(&mut out, n_r, &|c| {
                let (o, i) = (c / inner, c % inner);
                let mut acc = 0.0f64;
                for k in 0..n_r {
                    acc += eval_at((o * n_r + k) * inner + i).to_f64();
                }
                acc
            })?;
            Tensor::new(out_shape, T::buffer(out))
        }
    }
    .map_err(|e| anyhow!("{e}"))?;
    Ok((Value::Tensor(t), saved))
}

/// Step-by-step replay of the postfix program through the ordinary
/// primitive evaluator — the exact unfused semantics (symbolic zeros,
/// scalar arithmetic, integer wrapping, error messages and all). Leaves
/// are *moved* at their final textual use and every step goes through
/// [`eval_prim_inplace`], so a fused-but-replayed chain (integer dtypes,
/// mixed promotions) keeps the same in-place buffer reuse the unfused
/// pipeline would have had — replay is a fidelity fallback, never a
/// pessimization.
fn replay(expr: &FusedExpr, leaves: &mut [Value]) -> Result<Value> {
    let mut last_use: Vec<Option<usize>> = vec![None; leaves.len()];
    for (i, op) in expr.ops.iter().enumerate() {
        if let FusedOp::Input(k) = op {
            last_use[*k as usize] = Some(i);
        }
    }
    let mut stack: Vec<Value> = Vec::with_capacity(expr.max_stack);
    for (i, op) in expr.ops.iter().enumerate() {
        match op {
            FusedOp::Input(k) => {
                let k = *k as usize;
                // `leaves` is the call's private argument buffer, so the
                // final read may take the value (dying registers were
                // already moved in by the interpreter — uniqueness, and
                // therefore reuse, survives the replay).
                let v = if last_use[k] == Some(i) {
                    std::mem::replace(&mut leaves[k], Value::Unit)
                } else {
                    leaves[k].clone()
                };
                stack.push(v);
            }
            FusedOp::ConstF64(v) => stack.push(Value::F64(*v)),
            FusedOp::ConstI64(v) => stack.push(Value::I64(*v)),
            FusedOp::Un(p) => {
                let x = stack.pop().expect("validated");
                stack.push(eval_prim_inplace(*p, &mut [x])?);
            }
            FusedOp::Bin(p) => {
                let y = stack.pop().expect("validated");
                let x = stack.pop().expect("validated");
                stack.push(eval_prim_inplace(*p, &mut [x, y])?);
            }
            FusedOp::Where => {
                let b = stack.pop().expect("validated");
                let a = stack.pop().expect("validated");
                let c = stack.pop().expect("validated");
                stack.push(eval_prim_inplace(Prim::Where, &mut [c, a, b])?);
            }
            FusedOp::BroadcastTo(shape) => {
                let x = stack.pop().expect("validated");
                let s = Value::tuple(shape.iter().map(|&d| Value::I64(d as i64)).collect());
                stack.push(eval_prim_inplace(Prim::BroadcastTo, &mut [x, s])?);
            }
        }
    }
    let v = stack.pop().expect("validated: one value remains");
    // The trailing reduction replays through the standalone kernel — the
    // exact unfused semantics (ZeroT absorption, error messages and all).
    match expr.reduce {
        None => Ok(v),
        Some(FusedReduce::Sum) => eval_prim_inplace(Prim::ReduceSum, &mut [v]),
        Some(FusedReduce::SumTail) => eval_prim_inplace(Prim::SumTail, &mut [v]),
        Some(FusedReduce::SumAxis(ax)) => {
            eval_prim_inplace(Prim::ReduceSumAxis, &mut [v, Value::I64(ax as i64)])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::FusedOp as F;
    use crate::vm::prims::eval_prim;

    fn fused(n: usize, ops: Vec<F>) -> Value {
        Value::Fused(std::sync::Arc::new(FusedExpr::new(n, ops).unwrap()))
    }

    fn fused_red(n: usize, ops: Vec<F>, r: FusedReduce) -> Value {
        Value::Fused(std::sync::Arc::new(FusedExpr::with_reduce(n, ops, Some(r)).unwrap()))
    }

    fn t(v: &[f64]) -> Value {
        Value::Tensor(Tensor::from_f64(v))
    }

    #[test]
    fn fast_path_matches_unfused_chain() {
        // exp(x) * y + 2.0 over f64 tensors
        let e = fused(
            2,
            vec![
                F::Input(0),
                F::Un(Prim::Exp),
                F::Input(1),
                F::Bin(Prim::Mul),
                F::ConstF64(2.0),
                F::Bin(Prim::Add),
            ],
        );
        let mut args = vec![e, t(&[0.5, -1.0, 2.0]), t(&[1.0, 2.0, 3.0])];
        let (out, saved) = eval_fused(&mut args).unwrap();
        // Unfused oracle through eval_prim.
        let ex = eval_prim(Prim::Exp, &[t(&[0.5, -1.0, 2.0])]).unwrap();
        let m = eval_prim(Prim::Mul, &[ex, t(&[1.0, 2.0, 3.0])]).unwrap();
        let want = eval_prim(Prim::Add, &[m, Value::F64(2.0)]).unwrap();
        assert!(out.structural_eq(&want), "{out} vs {want}");
        assert!(saved >= 2, "two interior ops eliminated, got {saved}");
    }

    #[test]
    fn broadcasting_leaves() {
        // x[2,3] + row[3] fused with a scalar multiply
        let x = Tensor::from_f64_shaped(vec![1., 2., 3., 4., 5., 6.], vec![2, 3]).unwrap();
        let row = Tensor::from_f64(&[10., 20., 30.]);
        let e = fused(
            2,
            vec![
                F::Input(0),
                F::Input(1),
                F::Bin(Prim::Add),
                F::ConstF64(2.0),
                F::Bin(Prim::Mul),
            ],
        );
        let mut args = vec![e, Value::Tensor(x), Value::Tensor(row)];
        let (out, _) = eval_fused(&mut args).unwrap();
        let got = out.as_tensor().unwrap();
        assert_eq!(got.shape(), &[2, 3]);
        assert_eq!(got.as_f64_vec(), vec![22., 44., 66., 28., 50., 72.]);
    }

    #[test]
    fn zerot_and_scalars_replay_exactly() {
        // add absorbs ZeroT exactly as the unfused eval does.
        let e = fused(2, vec![F::Input(0), F::Input(1), F::Bin(Prim::Add)]);
        let mut args = vec![e.clone(), Value::ZeroT, t(&[1.0, 2.0])];
        let (out, saved) = eval_fused(&mut args).unwrap();
        assert!(out.structural_eq(&t(&[1.0, 2.0])));
        assert_eq!(saved, 0, "replay path saves nothing");
        // scalar-only chains return scalar values with integer semantics.
        let mut args = vec![e, Value::I64(3), Value::I64(4)];
        let (out, _) = eval_fused(&mut args).unwrap();
        assert!(matches!(out, Value::I64(7)));
    }

    #[test]
    fn i64_tensor_intermediates_replay() {
        // (a + b) * c with i64 a,b and f64 c: the intermediate is integral,
        // so the fast path must decline and the replay must match the
        // unfused chain bit-for-bit (wrapping add included).
        let a = Value::Tensor(Tensor::from_i64_shaped(vec![i64::MAX, 5], vec![2]).unwrap());
        let b = Value::Tensor(Tensor::from_i64_shaped(vec![1, 7], vec![2]).unwrap());
        let c = t(&[1.0, 2.0]);
        let e = fused(
            3,
            vec![F::Input(0), F::Input(1), F::Bin(Prim::Add), F::Input(2), F::Bin(Prim::Mul)],
        );
        let mut args = vec![e, a.clone(), b.clone(), c.clone()];
        let (out, _) = eval_fused(&mut args).unwrap();
        let s = eval_prim(Prim::Add, &[a, b]).unwrap();
        let want = eval_prim(Prim::Mul, &[s, c]).unwrap();
        assert!(out.structural_eq(&want));
    }

    #[test]
    fn unique_output_buffer_is_reused() {
        let before = crate::tensor::buffer_reuse_count();
        let e = fused(1, vec![F::Input(0), F::Un(Prim::Neg), F::Un(Prim::Exp)]);
        // The tensor moved into args is the only owner → its buffer hosts
        // the output.
        let mut args = vec![e, t(&[0.1, 0.2, 0.3])];
        let (out, saved) = eval_fused(&mut args).unwrap();
        assert!(saved >= 2, "interior + reuse, got {saved}");
        assert!(crate::tensor::buffer_reuse_count() > before);
        let got = out.as_tensor().unwrap().as_f64_vec();
        assert!((got[0] - (-0.1f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn shared_leaf_is_not_mutated() {
        let keep = Tensor::from_f64(&[1.0, 2.0]);
        let e = fused(1, vec![F::Input(0), F::Un(Prim::Neg)]);
        let mut args = vec![e, Value::Tensor(keep.clone())];
        let (out, _) = eval_fused(&mut args).unwrap();
        assert_eq!(out.as_tensor().unwrap().as_f64_vec(), vec![-1.0, -2.0]);
        // The retained reference still sees the original values.
        assert_eq!(keep.as_f64_vec(), vec![1.0, 2.0]);
    }

    #[test]
    fn static_broadcast_anchor_extends_output() {
        // broadcast_to(x[3], [2,3]) * 2.0
        let e = fused(
            1,
            vec![
                F::Input(0),
                F::BroadcastTo(vec![2, 3]),
                F::ConstF64(2.0),
                F::Bin(Prim::Mul),
            ],
        );
        let mut args = vec![e, t(&[1.0, 2.0, 3.0])];
        let (out, _) = eval_fused(&mut args).unwrap();
        let got = out.as_tensor().unwrap();
        assert_eq!(got.shape(), &[2, 3]);
        assert_eq!(got.as_f64_vec(), vec![2., 4., 6., 2., 4., 6.]);
    }

    #[test]
    fn fused_reductions_match_map_then_reduce() {
        let _g = pool::test_guard();
        let prev = pool::intra_op_threads();
        // Rows × odd column count: crosses FUSED_PAR_MIN_ELEMS with a
        // ragged chunk tail in the reduced fill.
        let m = 4099usize;
        let xs: Vec<f64> = (0..8 * m).map(|i| (i as f64 * 0.37).sin()).collect();
        let x = Tensor::from_f64_shaped(xs, vec![8, m]).unwrap();
        let ops = vec![
            F::Input(0),
            F::Un(Prim::Tanh),
            F::Input(0),
            F::Bin(Prim::Mul),
            F::ConstF64(0.5),
            F::Bin(Prim::Add),
        ];
        // Oracle: the unreduced fused map, then the standalone kernel.
        let map = {
            let mut args = vec![fused(1, ops.clone()), Value::Tensor(x.clone())];
            eval_fused(&mut args).unwrap().0
        };
        let cases = vec![
            (FusedReduce::Sum, eval_prim(Prim::ReduceSum, &[map.clone()]).unwrap()),
            (FusedReduce::SumTail, eval_prim(Prim::SumTail, &[map.clone()]).unwrap()),
            (
                FusedReduce::SumAxis(0),
                eval_prim(Prim::ReduceSumAxis, &[map.clone(), Value::I64(0)]).unwrap(),
            ),
            (
                FusedReduce::SumAxis(1),
                eval_prim(Prim::ReduceSumAxis, &[map.clone(), Value::I64(1)]).unwrap(),
            ),
        ];
        for (r, want) in cases {
            for lanes in [1usize, 2, 8] {
                pool::set_intra_op_threads(lanes);
                let mut args = vec![fused_red(1, ops.clone(), r), Value::Tensor(x.clone())];
                let (got, saved) = eval_fused(&mut args).unwrap();
                assert!(saved >= 2, "{r:?}: interior + map output eliminated, got {saved}");
                let g = got.as_tensor().unwrap();
                let w = want.as_tensor().unwrap();
                assert_eq!(g.shape(), w.shape(), "{r:?}");
                let same = g
                    .as_f64_vec()
                    .iter()
                    .zip(w.as_f64_vec())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "fused {r:?} differs from map-then-reduce at {lanes} lanes");
            }
        }
        pool::set_intra_op_threads(prev);
    }

    #[test]
    fn f32_reduction_narrows_like_the_kernels() {
        let x = Tensor::new(
            vec![2, 3],
            crate::tensor::Buffer::F32(vec![0.1, 0.7, -1.3, 2.2, 0.05, -0.6]),
        )
        .unwrap();
        let ops = vec![F::Input(0), F::Input(0), F::Bin(Prim::Mul)];
        let map = {
            let mut args = vec![fused(1, ops.clone()), Value::Tensor(x.clone())];
            eval_fused(&mut args).unwrap().0
        };
        let want = eval_prim(Prim::SumTail, &[map]).unwrap();
        let mut args = vec![fused_red(1, ops, FusedReduce::SumTail), Value::Tensor(x)];
        let (got, _) = eval_fused(&mut args).unwrap();
        let g = got.as_tensor().unwrap();
        assert_eq!(g.dtype(), DType::F32);
        assert!(got.structural_eq(&want), "{got} vs {want}");
    }

    #[test]
    fn sum_tail_on_rank1_is_identity() {
        // `ops::sum_tail` is the identity below rank 2; the fused form must
        // agree (and still apply the map).
        let e = fused_red(1, vec![F::Input(0), F::Un(Prim::Neg)], FusedReduce::SumTail);
        let mut args = vec![e, t(&[1.0, 2.0, 3.0])];
        let (out, _) = eval_fused(&mut args).unwrap();
        let g = out.as_tensor().unwrap();
        assert_eq!(g.shape(), &[3]);
        assert_eq!(g.as_f64_vec(), vec![-1.0, -2.0, -3.0]);
    }

    #[test]
    fn sum_axis_out_of_range_replays_to_kernel_error() {
        let e = fused_red(1, vec![F::Input(0), F::Un(Prim::Neg)], FusedReduce::SumAxis(5));
        let mut args = vec![e, t(&[1.0, 2.0])];
        let err = eval_fused(&mut args).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
    }

    #[test]
    fn planned_dispatch_matches_generic_and_counts() {
        use crate::vm::plan::PlanCache;
        let cache = PlanCache::new(1);
        cache.set_enabled(true);
        let s = cache.site(0).unwrap();
        let x = Tensor::from_f64_shaped(vec![1., 2., 3., 4., 5., 6.], vec![2, 3]).unwrap();
        let row = Tensor::from_f64(&[10., 20., 30.]);
        let ops = vec![F::Input(0), F::Input(1), F::Bin(Prim::Add), F::Un(Prim::Tanh)];
        let e = fused_red(2, ops, FusedReduce::SumAxis(0));
        let mut stats = ExecStats::default();

        let run_at = |stats: &mut ExecStats| {
            let mut args =
                vec![e.clone(), Value::Tensor(x.clone()), Value::Tensor(row.clone())];
            eval_fused_at(&mut args, Some((&cache, s)), stats).unwrap().0
        };
        let first = run_at(&mut stats);
        assert_eq!(stats.plans_compiled, 1);
        assert_eq!(stats.plan_hits, 0);
        let second = run_at(&mut stats);
        assert_eq!(stats.plan_hits, 1, "repeat shapes must hit the cached plan");

        // Planned results are bit-identical to the plan-less path.
        let generic = {
            let mut args =
                vec![e.clone(), Value::Tensor(x.clone()), Value::Tensor(row.clone())];
            eval_fused(&mut args).unwrap().0
        };
        assert!(first.structural_eq(&generic), "{first} vs {generic}");
        assert!(second.structural_eq(&generic));

        // A new leaf shape at the same site: miss, recompile, then hit.
        let x2 = Tensor::from_f64_shaped(vec![1.0; 12], vec![4, 3]).unwrap();
        let mut args = vec![e.clone(), Value::Tensor(x2), Value::Tensor(row.clone())];
        eval_fused_at(&mut args, Some((&cache, s)), &mut stats).unwrap();
        assert_eq!(stats.plan_shape_misses, 1);
        assert_eq!(stats.plans_compiled, 2);

        // Unkeyable leaves (ZeroT) bypass the tier without touching it.
        let before = cache.stats();
        let mut args = vec![e, Value::ZeroT, Value::Tensor(row)];
        eval_fused_at(&mut args, Some((&cache, s)), &mut stats).unwrap();
        assert_eq!(cache.stats(), before, "ZeroT must bypass, not count");
    }

    #[test]
    fn rank0_and_batch_of_1_take_the_plan_path() {
        use crate::vm::plan::PlanCache;
        let cache = PlanCache::new(2);
        cache.set_enabled(true);
        // Rank-0 output: full-sum reduction.
        let s0 = cache.site(0).unwrap();
        let e0 = fused_red(1, vec![F::Input(0), F::Un(Prim::Exp)], FusedReduce::Sum);
        for _ in 0..2 {
            let mut args = vec![e0.clone(), t(&[0.1, 0.2])];
            eval_fused_at(&mut args, Some((&cache, s0)), &mut ExecStats::default()).unwrap();
        }
        // Batch-of-1 leaf: shape [1, 2].
        let s1 = cache.site(1).unwrap();
        let e1 = fused(1, vec![F::Input(0), F::Un(Prim::Neg)]);
        for _ in 0..2 {
            let one = Tensor::from_f64_shaped(vec![1.0, 2.0], vec![1, 2]).unwrap();
            let mut args = vec![e1.clone(), Value::Tensor(one)];
            eval_fused_at(&mut args, Some((&cache, s1)), &mut ExecStats::default()).unwrap();
        }
        let st = cache.stats();
        assert_eq!(st.plans_compiled, 2);
        assert_eq!(st.plan_hits, 2, "rank-0 and batch-of-1 must hit plans, never bypass");
    }

    #[test]
    fn chunked_parallel_loop_is_bit_identical() {
        let _g = pool::test_guard();
        let prev = pool::intra_op_threads();
        // Big enough to cross FUSED_PAR_MIN_ELEMS with several chunks, and
        // not chunk-aligned so the ragged tail is exercised.
        let n = 3 * pool::FUSED_CHUNK_ELEMS + 17;
        let xs: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let ops = vec![
            F::Input(0),
            F::Un(Prim::Tanh),
            F::Input(0),
            F::Bin(Prim::Mul),
            F::ConstF64(0.5),
            F::Bin(Prim::Add),
        ];
        let run = |lanes: usize| {
            pool::set_intra_op_threads(lanes);
            // A uniquely-owned leaf: the kernel steals it for the output,
            // so the chunked FromOut read path is exercised too.
            let mut args =
                vec![fused(1, ops.clone()), Value::Tensor(Tensor::from_f64(&xs))];
            let (out, saved) = eval_fused(&mut args).unwrap();
            assert!(saved >= 1, "dying unique leaf must be reused");
            out.as_tensor().unwrap().as_f64_vec()
        };
        let seq = run(1);
        for lanes in [2, 8] {
            let par = run(lanes);
            let same = seq.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "fused loop differs at {lanes} lanes");
        }
        pool::set_intra_op_threads(prev);
    }
}

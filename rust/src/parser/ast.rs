//! Abstract syntax tree for the Python subset.
//!
//! Mutating statements (`x[i] = v`, `x += y`) are *representable as parse
//! errors only*: the parser recognizes them and rejects them with the
//! targeted message the paper calls for (§4.1 "We currently forbid these
//! statements in Myia").

/// Binary arithmetic operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    FloorDiv,
    Mod,
    Pow,
    MatMul,
}

/// Comparison operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Gt,
    Le,
    Ge,
    Eq,
    Ne,
}

/// Expressions. Every variant carries the source line for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Int(i64, usize),
    Float(f64, usize),
    Bool(bool, usize),
    NoneLit(usize),
    Str(String, usize),
    Name(String, usize),
    /// `(a, b, c)` — a tuple literal.
    Tuple(Vec<Expr>, usize),
    /// `[a, b, c]` — sugar for a cons list `(a, (b, (c, None)))`.
    List(Vec<Expr>, usize),
    BinOp(BinOp, Box<Expr>, Box<Expr>, usize),
    /// Unary minus.
    Neg(Box<Expr>, usize),
    Compare(CmpOp, Box<Expr>, Box<Expr>, usize),
    /// Short-circuit `and` / `or` (lowered to switch over thunks).
    And(Box<Expr>, Box<Expr>, usize),
    Or(Box<Expr>, Box<Expr>, usize),
    Not(Box<Expr>, usize),
    Call(Box<Expr>, Vec<Expr>, usize),
    /// `x[i]` — tuple indexing.
    Index(Box<Expr>, Box<Expr>, usize),
    Lambda(Vec<String>, Box<Expr>, usize),
    /// `a if cond else b`.
    IfExp(Box<Expr>, Box<Expr>, Box<Expr>, usize),
}

impl Expr {
    /// Source line of the expression.
    pub fn line(&self) -> usize {
        use Expr::*;
        match self {
            Int(_, l) | Float(_, l) | Bool(_, l) | NoneLit(l) | Str(_, l) | Name(_, l)
            | Tuple(_, l) | List(_, l) | BinOp(_, _, _, l) | Neg(_, l) | Compare(_, _, _, l)
            | And(_, _, l) | Or(_, _, l) | Not(_, l) | Call(_, _, l) | Index(_, _, l)
            | Lambda(_, _, l) | IfExp(_, _, _, l) => *l,
        }
    }
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `def name(params): body`
    FuncDef { name: String, params: Vec<String>, body: Vec<Stmt>, line: usize },
    Return(Option<Expr>, usize),
    /// `if cond: then else: orelse` (elif chains are nested in orelse).
    If { cond: Expr, then: Vec<Stmt>, orelse: Vec<Stmt>, line: usize },
    While { cond: Expr, body: Vec<Stmt>, line: usize },
    /// `for var in range(count): body` — the only supported `for` form.
    ForRange { var: String, count: Expr, body: Vec<Stmt>, line: usize },
    /// `a = expr` or `a, b = expr` (tuple destructuring).
    Assign { targets: Vec<String>, value: Expr, line: usize },
    ExprStmt(Expr, usize),
    Pass(usize),
}

impl Stmt {
    pub fn line(&self) -> usize {
        use Stmt::*;
        match self {
            FuncDef { line, .. }
            | If { line, .. }
            | While { line, .. }
            | ForRange { line, .. }
            | Assign { line, .. } => *line,
            Return(_, l) | ExprStmt(_, l) | Pass(l) => *l,
        }
    }
}

/// Collect the names assigned anywhere in a statement list, *not* descending
/// into nested function definitions (their scopes are separate). Used by the
/// lowering of `if`/`while` to compute merged ("phi") variables.
pub fn assigned_names(stmts: &[Stmt]) -> Vec<String> {
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    fn walk(stmts: &[Stmt], out: &mut Vec<String>, seen: &mut std::collections::HashSet<String>) {
        for s in stmts {
            match s {
                Stmt::Assign { targets, .. } => {
                    for t in targets {
                        if seen.insert(t.clone()) {
                            out.push(t.clone());
                        }
                    }
                }
                Stmt::ForRange { var, body, .. } => {
                    if seen.insert(var.clone()) {
                        out.push(var.clone());
                    }
                    walk(body, out, seen);
                }
                Stmt::If { then, orelse, .. } => {
                    walk(then, out, seen);
                    walk(orelse, out, seen);
                }
                Stmt::While { body, .. } => walk(body, out, seen),
                Stmt::FuncDef { name, .. } => {
                    // the *binding* of the function name counts
                    if seen.insert(name.clone()) {
                        out.push(name.clone());
                    }
                }
                _ => {}
            }
        }
    }
    walk(stmts, &mut out, &mut seen);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assigned_names_ignores_nested_functions() {
        let stmts = vec![
            Stmt::Assign { targets: vec!["a".into()], value: Expr::Int(1, 1), line: 1 },
            Stmt::FuncDef {
                name: "g".into(),
                params: vec![],
                body: vec![Stmt::Assign {
                    targets: vec!["hidden".into()],
                    value: Expr::Int(2, 2),
                    line: 2,
                }],
                line: 2,
            },
            Stmt::If {
                cond: Expr::Bool(true, 3),
                then: vec![Stmt::Assign { targets: vec!["b".into()], value: Expr::Int(3, 3), line: 3 }],
                orelse: vec![],
                line: 3,
            },
        ];
        let names = assigned_names(&stmts);
        assert_eq!(names, vec!["a".to_string(), "g".to_string(), "b".to_string()]);
    }

    #[test]
    fn line_accessors() {
        assert_eq!(Expr::Int(1, 42).line(), 42);
        assert_eq!(Stmt::Pass(7).line(), 7);
    }
}

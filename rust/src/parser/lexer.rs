//! Indentation-aware lexer for the Python-subset front end (§4.1).
//!
//! Produces a token stream with explicit `Indent`/`Dedent`/`Newline` tokens,
//! Python-style: blank lines and comments are skipped, and newlines inside
//! parentheses/brackets are implicit continuations.

use std::fmt;

/// A lexical token with its source position.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: Tok,
    pub line: usize,
    pub col: usize,
}

/// Token kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    // literals & names
    Int(i64),
    Float(f64),
    Str(String),
    Name(String),
    // keywords
    Def,
    Return,
    If,
    Elif,
    Else,
    While,
    For,
    In,
    Lambda,
    True,
    False,
    None_,
    And,
    Or,
    Not,
    Pass,
    // operators
    Plus,
    Minus,
    Star,
    Slash,
    DoubleSlash,
    Percent,
    DoubleStar,
    At,
    Lt,
    Gt,
    Le,
    Ge,
    EqEq,
    NotEq,
    Assign,
    // rejected-but-recognized (for targeted error messages, §4.1)
    AugAssign(String),
    // punctuation
    LParen,
    RParen,
    LBracket,
    RBracket,
    Comma,
    Colon,
    Dot,
    // layout
    Newline,
    Indent,
    Dedent,
    Eof,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self)
    }
}

/// Lexer error with position.
#[derive(Debug, Clone, PartialEq)]
pub struct LexError {
    pub message: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lex error at {}:{}: {}", self.line, self.col, self.message)
    }
}

impl std::error::Error for LexError {}

/// Tokenize a full source file.
pub fn lex(source: &str) -> Result<Vec<Token>, LexError> {
    let mut tokens = Vec::new();
    let mut indents: Vec<usize> = vec![0];
    let mut paren_depth = 0usize;
    let chars: Vec<char> = source.chars().collect();
    let mut pos = 0usize;
    let mut line = 1usize;
    let mut col = 1usize;
    let mut at_line_start = true;

    macro_rules! err {
        ($msg:expr) => {
            return Err(LexError { message: $msg.to_string(), line, col })
        };
    }

    while pos < chars.len() {
        // Handle indentation at line starts (outside brackets).
        if at_line_start && paren_depth == 0 {
            let mut indent = 0usize;
            let start = pos;
            while pos < chars.len() && (chars[pos] == ' ' || chars[pos] == '\t') {
                indent += if chars[pos] == '\t' { 8 } else { 1 };
                pos += 1;
            }
            col += pos - start;
            // Blank line or comment-only line: consume to newline, emit nothing.
            if pos >= chars.len() || chars[pos] == '\n' || chars[pos] == '#' {
                while pos < chars.len() && chars[pos] != '\n' {
                    pos += 1;
                }
                if pos < chars.len() {
                    pos += 1;
                    line += 1;
                    col = 1;
                }
                continue;
            }
            let current = *indents.last().unwrap();
            if indent > current {
                indents.push(indent);
                tokens.push(Token { kind: Tok::Indent, line, col });
            } else {
                while indent < *indents.last().unwrap() {
                    indents.pop();
                    tokens.push(Token { kind: Tok::Dedent, line, col });
                }
                if indent != *indents.last().unwrap() {
                    err!("inconsistent indentation");
                }
            }
            at_line_start = false;
            continue;
        }

        let c = chars[pos];
        let tline = line;
        let tcol = col;
        macro_rules! push {
            ($kind:expr, $len:expr) => {{
                tokens.push(Token { kind: $kind, line: tline, col: tcol });
                pos += $len;
                col += $len;
            }};
        }

        match c {
            ' ' | '\t' => {
                pos += 1;
                col += 1;
            }
            '#' => {
                while pos < chars.len() && chars[pos] != '\n' {
                    pos += 1;
                }
            }
            '\n' => {
                if paren_depth == 0 {
                    // collapse consecutive newlines
                    if !matches!(tokens.last().map(|t| &t.kind), Some(Tok::Newline) | None) {
                        tokens.push(Token { kind: Tok::Newline, line, col });
                    }
                    at_line_start = true;
                }
                pos += 1;
                line += 1;
                col = 1;
            }
            '\\' if pos + 1 < chars.len() && chars[pos + 1] == '\n' => {
                pos += 2;
                line += 1;
                col = 1;
            }
            '(' => {
                paren_depth += 1;
                push!(Tok::LParen, 1);
            }
            ')' => {
                paren_depth = paren_depth.saturating_sub(1);
                push!(Tok::RParen, 1);
            }
            '[' => {
                paren_depth += 1;
                push!(Tok::LBracket, 1);
            }
            ']' => {
                paren_depth = paren_depth.saturating_sub(1);
                push!(Tok::RBracket, 1);
            }
            ',' => push!(Tok::Comma, 1),
            ':' => push!(Tok::Colon, 1),
            '.' if !chars.get(pos + 1).map(|c| c.is_ascii_digit()).unwrap_or(false) => {
                push!(Tok::Dot, 1)
            }
            '+' if chars.get(pos + 1) == Some(&'=') => push!(Tok::AugAssign("+=".into()), 2),
            '-' if chars.get(pos + 1) == Some(&'=') => push!(Tok::AugAssign("-=".into()), 2),
            '*' if chars.get(pos + 1) == Some(&'=') => push!(Tok::AugAssign("*=".into()), 2),
            '/' if chars.get(pos + 1) == Some(&'=') => push!(Tok::AugAssign("/=".into()), 2),
            '+' => push!(Tok::Plus, 1),
            '-' => push!(Tok::Minus, 1),
            '*' if chars.get(pos + 1) == Some(&'*') => push!(Tok::DoubleStar, 2),
            '*' => push!(Tok::Star, 1),
            '/' if chars.get(pos + 1) == Some(&'/') => push!(Tok::DoubleSlash, 2),
            '/' => push!(Tok::Slash, 1),
            '%' => push!(Tok::Percent, 1),
            '@' => push!(Tok::At, 1),
            '<' if chars.get(pos + 1) == Some(&'=') => push!(Tok::Le, 2),
            '<' => push!(Tok::Lt, 1),
            '>' if chars.get(pos + 1) == Some(&'=') => push!(Tok::Ge, 2),
            '>' => push!(Tok::Gt, 1),
            '=' if chars.get(pos + 1) == Some(&'=') => push!(Tok::EqEq, 2),
            '=' => push!(Tok::Assign, 1),
            '!' if chars.get(pos + 1) == Some(&'=') => push!(Tok::NotEq, 2),
            '\'' | '"' => {
                let quote = c;
                let mut s = String::new();
                let mut p = pos + 1;
                while p < chars.len() && chars[p] != quote && chars[p] != '\n' {
                    if chars[p] == '\\' && p + 1 < chars.len() {
                        p += 1;
                        s.push(match chars[p] {
                            'n' => '\n',
                            't' => '\t',
                            other => other,
                        });
                    } else {
                        s.push(chars[p]);
                    }
                    p += 1;
                }
                if p >= chars.len() || chars[p] != quote {
                    err!("unterminated string literal");
                }
                let len = p + 1 - pos;
                push!(Tok::Str(s), len);
            }
            _ if c.is_ascii_digit() || (c == '.' && chars.get(pos + 1).map(|d| d.is_ascii_digit()).unwrap_or(false)) => {
                let start = pos;
                let mut is_float = false;
                while pos < chars.len()
                    && (chars[pos].is_ascii_digit()
                        || chars[pos] == '.'
                        || chars[pos] == 'e'
                        || chars[pos] == 'E'
                        || ((chars[pos] == '+' || chars[pos] == '-')
                            && matches!(chars.get(pos.wrapping_sub(1)), Some('e') | Some('E'))))
                {
                    if chars[pos] == '.' || chars[pos] == 'e' || chars[pos] == 'E' {
                        is_float = true;
                    }
                    pos += 1;
                }
                let text: String = chars[start..pos].iter().collect();
                col += pos - start;
                let kind = if is_float {
                    Tok::Float(text.parse().map_err(|_| LexError {
                        message: format!("bad float literal {text}"),
                        line: tline,
                        col: tcol,
                    })?)
                } else {
                    Tok::Int(text.parse().map_err(|_| LexError {
                        message: format!("bad int literal {text}"),
                        line: tline,
                        col: tcol,
                    })?)
                };
                tokens.push(Token { kind, line: tline, col: tcol });
            }
            _ if c.is_ascii_alphabetic() || c == '_' => {
                let start = pos;
                while pos < chars.len() && (chars[pos].is_ascii_alphanumeric() || chars[pos] == '_') {
                    pos += 1;
                }
                let text: String = chars[start..pos].iter().collect();
                col += pos - start;
                let kind = match text.as_str() {
                    "def" => Tok::Def,
                    "return" => Tok::Return,
                    "if" => Tok::If,
                    "elif" => Tok::Elif,
                    "else" => Tok::Else,
                    "while" => Tok::While,
                    "for" => Tok::For,
                    "in" => Tok::In,
                    "lambda" => Tok::Lambda,
                    "True" => Tok::True,
                    "False" => Tok::False,
                    "None" => Tok::None_,
                    "and" => Tok::And,
                    "or" => Tok::Or,
                    "not" => Tok::Not,
                    "pass" => Tok::Pass,
                    _ => Tok::Name(text),
                };
                tokens.push(Token { kind, line: tline, col: tcol });
            }
            _ => err!(format!("unexpected character {c:?}")),
        }
    }

    // Final newline + dedents.
    if !matches!(tokens.last().map(|t| &t.kind), Some(Tok::Newline) | None) {
        tokens.push(Token { kind: Tok::Newline, line, col });
    }
    while indents.len() > 1 {
        indents.pop();
        tokens.push(Token { kind: Tok::Dedent, line, col });
    }
    tokens.push(Token { kind: Tok::Eof, line, col });
    Ok(tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn simple_tokens() {
        let k = kinds("x = 1 + 2.5");
        assert_eq!(
            k,
            vec![
                Tok::Name("x".into()),
                Tok::Assign,
                Tok::Int(1),
                Tok::Plus,
                Tok::Float(2.5),
                Tok::Newline,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn keywords_and_operators() {
        let k = kinds("def f(x):\n    return x ** 3\n");
        assert!(k.contains(&Tok::Def));
        assert!(k.contains(&Tok::Indent));
        assert!(k.contains(&Tok::Return));
        assert!(k.contains(&Tok::DoubleStar));
        assert!(k.contains(&Tok::Dedent));
    }

    #[test]
    fn indentation_nesting() {
        let k = kinds("if a:\n  if b:\n    x = 1\n  y = 2\nz = 3\n");
        let indents = k.iter().filter(|t| **t == Tok::Indent).count();
        let dedents = k.iter().filter(|t| **t == Tok::Dedent).count();
        assert_eq!(indents, 2);
        assert_eq!(dedents, 2);
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let k = kinds("x = 1\n\n# comment line\n   # indented comment\ny = 2\n");
        let names: Vec<_> = k
            .iter()
            .filter_map(|t| match t {
                Tok::Name(n) => Some(n.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(names, vec!["x", "y"]);
        // no stray indents from the indented comment
        assert!(!k.contains(&Tok::Indent));
    }

    #[test]
    fn parens_allow_newlines() {
        let k = kinds("x = f(1,\n      2)\ny = 3\n");
        let newlines = k.iter().filter(|t| **t == Tok::Newline).count();
        assert_eq!(newlines, 2); // one per logical line
    }

    #[test]
    fn augmented_assign_recognized() {
        let k = kinds("x += 1");
        assert!(matches!(&k[1], Tok::AugAssign(s) if s == "+="));
    }

    #[test]
    fn string_literals() {
        let k = kinds(r#"raise_("bad \"thing\"\n")"#);
        assert!(k.iter().any(|t| matches!(t, Tok::Str(s) if s.contains("bad \"thing\"\n"))));
        assert!(lex("x = 'unterminated").is_err());
    }

    #[test]
    fn comparison_operators() {
        let k = kinds("a <= b != c == d >= e < f > g");
        assert!(k.contains(&Tok::Le));
        assert!(k.contains(&Tok::NotEq));
        assert!(k.contains(&Tok::EqEq));
        assert!(k.contains(&Tok::Ge));
        assert!(k.contains(&Tok::Lt));
        assert!(k.contains(&Tok::Gt));
    }

    #[test]
    fn scientific_notation() {
        let k = kinds("x = 1e-3 + 2.5E+2");
        assert!(k.contains(&Tok::Float(1e-3)));
        assert!(k.contains(&Tok::Float(2.5e2)));
    }

    #[test]
    fn bad_char_errors() {
        assert!(lex("x = $").is_err());
        let e = lex("x = $").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn matmul_and_floordiv() {
        let k = kinds("a @ b // c % d");
        assert!(k.contains(&Tok::At));
        assert!(k.contains(&Tok::DoubleSlash));
        assert!(k.contains(&Tok::Percent));
    }
}

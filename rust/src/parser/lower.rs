//! AST → IR lowering (§4.1).
//!
//! "Most of Python's features, such as functions, conditionals, and loops,
//! can readily be parsed into our functional representation":
//!
//! * nested `def`s and `lambda`s become nested graphs; a reference to an
//!   outer variable becomes a direct pointer to the outer graph's node (the
//!   IR's closure mechanism — no explicit capture lists);
//! * `if` lowers to `switch(cond, then_thunk, else_thunk)()`, with the code
//!   *after* the `if` lowered once into a continuation graph whose
//!   parameters are the variables assigned in either branch (the functional
//!   equivalent of SSA phi nodes);
//! * `while` lowers to a tail-recursive header graph whose parameters are
//!   the loop variables; `for i in range(n)` desugars to a `while`;
//! * `and`/`or`/ternary lower to `switch` over thunks, preserving
//!   short-circuit semantics (vital for recursive base cases).
//!
//! Scoping is SSA-like: a closure captures the *binding at its definition
//! point*. In the pure subset this differs from CPython's late binding only
//! for programs that rebind a captured variable after the closure is made —
//! exactly the mutation-flavored pattern the paper excludes.

use super::ast::{assigned_names, BinOp, CmpOp, Expr, Stmt};
use crate::ir::{Const, GraphId, MacroOp, Module, NodeId, Prim};
use std::collections::HashMap;
use std::fmt;

/// Lowering error with source line.
#[derive(Debug, Clone, PartialEq)]
pub struct LowerError {
    pub message: String,
    pub line: usize,
}

impl fmt::Display for LowerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lowering error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for LowerError {}

type LResult<T> = Result<T, LowerError>;

/// Scope chain: innermost map last. Assignments bind in the innermost map;
/// lookups walk outward.
type Env = Vec<HashMap<String, NodeId>>;

/// What a block does when control falls off its end.
#[derive(Debug, Clone)]
enum FallOff {
    /// Return `None` (function bodies).
    Unit,
    /// Tail-call a continuation/loop-header graph with the current values of
    /// the named variables.
    CallCont { graph: GraphId, vars: Vec<String> },
}

/// Lower a parsed module; returns the top-level function name → graph map.
///
/// Top-level definitions are mutually visible (two-pass binding), so a
/// function may reference one defined later in the file.
pub fn lower_module(m: &mut Module, stmts: &[Stmt]) -> LResult<HashMap<String, GraphId>> {
    let mut lower = Lower { m, thunk_counter: 0 };
    let mut env: Env = vec![HashMap::new()];
    let mut graphs = HashMap::new();
    // Pass 1: create graphs and bind all top-level names.
    for s in stmts {
        match s {
            Stmt::FuncDef { name, .. } => {
                let g = lower.m.add_graph(name.clone());
                let gc = lower.m.graph_constant(g);
                env.last_mut().unwrap().insert(name.clone(), gc);
                graphs.insert(name.clone(), g);
            }
            Stmt::Pass(_) => {}
            other => {
                return Err(LowerError {
                    message: "only `def` is allowed at module top level".into(),
                    line: other.line(),
                })
            }
        }
    }
    // Pass 2: lower bodies.
    for s in stmts {
        if let Stmt::FuncDef { name, params, body, .. } = s {
            lower.fill_function(graphs[name], name, params, body, &env)?;
        }
    }
    Ok(graphs)
}

/// Convenience: parse and lower a source string.
pub fn compile_source(m: &mut Module, source: &str) -> crate::Result<HashMap<String, GraphId>> {
    let ast = super::parse::parse_module(source).map_err(|e| anyhow::anyhow!("{e}"))?;
    lower_module(m, &ast).map_err(|e| anyhow::anyhow!("{e}"))
}

struct Lower<'m> {
    m: &'m mut Module,
    thunk_counter: usize,
}

/// True if every control path through the block ends in `return`.
fn block_returns(stmts: &[Stmt]) -> bool {
    match stmts.last() {
        Some(Stmt::Return(..)) => true,
        Some(Stmt::If { then, orelse, .. }) => {
            !orelse.is_empty() && block_returns(then) && block_returns(orelse)
        }
        _ => false,
    }
}

impl<'m> Lower<'m> {
    fn fresh_name(&mut self, base: &str) -> String {
        self.thunk_counter += 1;
        format!("{base}#{}", self.thunk_counter)
    }

    fn lower_function(
        &mut self,
        name: &str,
        params: &[String],
        body: &[Stmt],
        env: &Env,
    ) -> LResult<GraphId> {
        let g = self.m.add_graph(name);
        self.fill_function(g, name, params, body, env)?;
        Ok(g)
    }

    /// Lower params + body into an already-created (empty) graph.
    fn fill_function(
        &mut self,
        g: GraphId,
        name: &str,
        params: &[String],
        body: &[Stmt],
        env: &Env,
    ) -> LResult<()> {
        let mut inner: HashMap<String, NodeId> = HashMap::new();
        // Bind the function's own name first so recursion works.
        let gc = self.m.graph_constant(g);
        inner.insert(name.to_string(), gc);
        for p in params {
            let pn = self.m.add_parameter(g, p.clone());
            inner.insert(p.clone(), pn);
        }
        let mut env2 = env.clone();
        env2.push(inner);
        let ret = self.lower_block(g, body, env2, FallOff::Unit)?;
        self.m.set_return(g, ret);
        Ok(())
    }

    /// Lower a statement list into graph `g`; returns the block's value.
    fn lower_block(
        &mut self,
        g: GraphId,
        stmts: &[Stmt],
        mut env: Env,
        falloff: FallOff,
    ) -> LResult<NodeId> {
        let mut effects: Vec<NodeId> = Vec::new();
        let mut i = 0usize;
        while i < stmts.len() {
            let stmt = &stmts[i];
            let rest = &stmts[i + 1..];
            match stmt {
                Stmt::Pass(_) => {}
                Stmt::FuncDef { name, params, body, .. } => {
                    let fg = self.lower_function(name, params, body, &env)?;
                    let gc = self.m.graph_constant(fg);
                    env.last_mut().unwrap().insert(name.clone(), gc);
                }
                Stmt::Assign { targets, value, line } => {
                    let v = self.lower_expr(g, value, &env)?;
                    if targets.len() == 1 {
                        self.m.name_node(v, targets[0].clone());
                        env.last_mut().unwrap().insert(targets[0].clone(), v);
                    } else {
                        for (idx, t) in targets.iter().enumerate() {
                            let ic = self.m.constant(Const::I64(idx as i64));
                            let item = self.m.apply_prim(g, Prim::TupleGetItem, &[v, ic]);
                            self.m.name_node(item, t.clone());
                            env.last_mut().unwrap().insert(t.clone(), item);
                        }
                        let _ = line;
                    }
                }
                Stmt::ExprStmt(e, _) => {
                    let v = self.lower_expr(g, e, &env)?;
                    effects.push(v);
                }
                Stmt::Return(e, _) => {
                    let v = match e {
                        Some(e) => self.lower_expr(g, e, &env)?,
                        None => self.m.constant(Const::Unit),
                    };
                    return Ok(self.sequence_effects(g, effects, v));
                }
                Stmt::If { cond, then, orelse, .. } => {
                    let v = self.lower_if(g, cond, then, orelse, rest, env, falloff)?;
                    return Ok(self.sequence_effects(g, effects, v));
                }
                Stmt::While { cond, body, .. } => {
                    let v = self.lower_while(g, cond, body, rest, env, falloff)?;
                    return Ok(self.sequence_effects(g, effects, v));
                }
                Stmt::ForRange { var, count, body, line } => {
                    // Desugar: hidden = count; var = 0; while var < hidden:
                    //   body; var = var + 1
                    let hidden = format!("__range_limit#{line}_{i}");
                    let mut new_body = body.clone();
                    new_body.push(Stmt::Assign {
                        targets: vec![var.clone()],
                        value: Expr::BinOp(
                            BinOp::Add,
                            Box::new(Expr::Name(var.clone(), *line)),
                            Box::new(Expr::Int(1, *line)),
                            *line,
                        ),
                        line: *line,
                    });
                    let mut desugared = vec![
                        Stmt::Assign { targets: vec![hidden.clone()], value: count.clone(), line: *line },
                        Stmt::Assign { targets: vec![var.clone()], value: Expr::Int(0, *line), line: *line },
                        Stmt::While {
                            cond: Expr::Compare(
                                CmpOp::Lt,
                                Box::new(Expr::Name(var.clone(), *line)),
                                Box::new(Expr::Name(hidden, *line)),
                                *line,
                            ),
                            body: new_body,
                            line: *line,
                        },
                    ];
                    desugared.extend_from_slice(rest);
                    let v = self.lower_block(g, &desugared, env, falloff)?;
                    return Ok(self.sequence_effects(g, effects, v));
                }
            }
            i += 1;
        }
        // Fell off the end of the block.
        let v = match falloff {
            FallOff::Unit => self.m.constant(Const::Unit),
            FallOff::CallCont { graph, vars } => {
                let gc = self.m.graph_constant(graph);
                let mut inputs = vec![gc];
                for name in &vars {
                    inputs.push(self.lookup(name, &env, 0)?);
                }
                self.m.apply(g, inputs)
            }
        };
        Ok(self.sequence_effects(g, effects, v))
    }

    /// Thread impure expression-statement results into the block value so
    /// they are evaluated (and ordered before the value).
    fn sequence_effects(&mut self, g: GraphId, effects: Vec<NodeId>, value: NodeId) -> NodeId {
        if effects.is_empty() {
            return value;
        }
        let mut inputs = vec![self.m.constant(Const::Prim(Prim::MakeTuple))];
        inputs.extend(effects);
        inputs.push(value);
        let n = inputs.len() - 1;
        let tup = self.m.apply(g, inputs);
        let idx = self.m.constant(Const::I64((n - 1) as i64));
        self.m.apply_prim(g, Prim::TupleGetItem, &[tup, idx])
    }

    fn lower_if(
        &mut self,
        g: GraphId,
        cond: &Expr,
        then: &[Stmt],
        orelse: &[Stmt],
        rest: &[Stmt],
        env: Env,
        falloff: FallOff,
    ) -> LResult<NodeId> {
        let cond_node = self.lower_expr(g, cond, &env)?;

        // Decide whether we need a continuation graph for `rest`.
        let both_return = block_returns(then) && !orelse.is_empty() && block_returns(orelse);
        let branch_falloff: FallOff;
        if both_return || rest.is_empty() {
            branch_falloff = falloff.clone();
        } else {
            // merged variables: assigned in either branch AND (defined before
            // or assigned in both) — the phi set.
            let a_then = assigned_names(then);
            let a_else = assigned_names(orelse);
            let mut merged: Vec<String> = Vec::new();
            for n in a_then.iter().chain(a_else.iter()) {
                if merged.contains(n) {
                    continue;
                }
                let defined_before = self.lookup(n, &env, 0).is_ok();
                let in_both = a_then.contains(n) && a_else.contains(n);
                if defined_before || in_both {
                    merged.push(n.clone());
                }
            }
            let kname = self.fresh_name("if_cont");
            let k = self.m.add_graph(kname);
            let mut kenv = env.clone();
            for name in &merged {
                let p = self.m.add_parameter(k, name.clone());
                kenv.last_mut().unwrap().insert(name.clone(), p);
            }
            let kret = self.lower_block(k, rest, kenv, falloff)?;
            self.m.set_return(k, kret);
            branch_falloff = FallOff::CallCont { graph: k, vars: merged };
        }

        let tt = self.lower_thunk(then, &env, branch_falloff.clone(), "if_true")?;
        let ff = self.lower_thunk(orelse, &env, branch_falloff, "if_false")?;
        let ttc = self.m.graph_constant(tt);
        let ffc = self.m.graph_constant(ff);
        let sel = self.m.apply_prim(g, Prim::Switch, &[cond_node, ttc, ffc]);
        Ok(self.m.apply(g, vec![sel]))
    }

    fn lower_while(
        &mut self,
        g: GraphId,
        cond: &Expr,
        body: &[Stmt],
        rest: &[Stmt],
        env: Env,
        falloff: FallOff,
    ) -> LResult<NodeId> {
        // Loop variables: assigned in the body and already defined.
        let loop_vars: Vec<String> = assigned_names(body)
            .into_iter()
            .filter(|n| self.lookup(n, &env, 0).is_ok())
            .collect();

        let wname = self.fresh_name("while_header");
        let w = self.m.add_graph(wname);
        let mut wenv = env.clone();
        for name in &loop_vars {
            let p = self.m.add_parameter(w, name.clone());
            wenv.last_mut().unwrap().insert(name.clone(), p);
        }
        let cond_node = self.lower_expr(w, cond, &wenv)?;

        // Body thunk: run the body, then tail-call the header again.
        let bt = self.lower_thunk(
            body,
            &wenv,
            FallOff::CallCont { graph: w, vars: loop_vars.clone() },
            "while_body",
        )?;
        // Exit thunk: the rest of the enclosing block.
        let et = self.lower_thunk(rest, &wenv, falloff, "while_exit")?;

        let btc = self.m.graph_constant(bt);
        let etc = self.m.graph_constant(et);
        let sel = self.m.apply_prim(w, Prim::Switch, &[cond_node, btc, etc]);
        let wret = self.m.apply(w, vec![sel]);
        self.m.set_return(w, wret);

        // Kick off the loop with the current values.
        let wc = self.m.graph_constant(w);
        let mut inputs = vec![wc];
        for name in &loop_vars {
            inputs.push(self.lookup(name, &env, 0)?);
        }
        Ok(self.m.apply(g, inputs))
    }

    /// A zero-parameter nested graph running `stmts`.
    fn lower_thunk(&mut self, stmts: &[Stmt], env: &Env, falloff: FallOff, base: &str) -> LResult<GraphId> {
        let name = self.fresh_name(base);
        let t = self.m.add_graph(name);
        let ret = self.lower_block(t, stmts, env.clone(), falloff)?;
        self.m.set_return(t, ret);
        Ok(t)
    }

    /// A zero-parameter nested graph evaluating one expression.
    fn expr_thunk(&mut self, g_env: &Env, e: &Expr, base: &str) -> LResult<NodeId> {
        let name = self.fresh_name(base);
        let t = self.m.add_graph(name);
        let v = self.lower_expr(t, e, g_env)?;
        self.m.set_return(t, v);
        Ok(self.m.graph_constant(t))
    }

    fn lookup(&mut self, name: &str, env: &Env, line: usize) -> LResult<NodeId> {
        for scope in env.iter().rev() {
            if let Some(&n) = scope.get(name) {
                return Ok(n);
            }
        }
        // Builtins.
        if let Some(p) = builtin(name) {
            return Ok(self.m.constant(Const::Prim(p)));
        }
        match name {
            "grad" => return Ok(self.m.constant(Const::Macro(MacroOp::Grad))),
            "value_and_grad" => return Ok(self.m.constant(Const::Macro(MacroOp::ValueAndGrad))),
            "jfwd" => return Ok(self.m.constant(Const::Macro(MacroOp::Jfwd))),
            _ => {}
        }
        Err(LowerError { message: format!("undefined name `{name}`"), line })
    }

    fn lower_expr(&mut self, g: GraphId, e: &Expr, env: &Env) -> LResult<NodeId> {
        Ok(match e {
            Expr::Int(v, _) => self.m.constant(Const::I64(*v)),
            Expr::Float(v, _) => self.m.constant(Const::F64(*v)),
            Expr::Bool(v, _) => self.m.constant(Const::Bool(*v)),
            Expr::NoneLit(_) => self.m.constant(Const::Unit),
            Expr::Str(s, _) => self.m.constant(Const::Str(s.clone())),
            Expr::Name(n, line) => self.lookup(n, env, *line)?,
            Expr::Tuple(items, _) => {
                let mut args = Vec::with_capacity(items.len());
                for it in items {
                    args.push(self.lower_expr(g, it, env)?);
                }
                let mut inputs = vec![self.m.constant(Const::Prim(Prim::MakeTuple))];
                inputs.extend(args);
                self.m.apply(g, inputs)
            }
            Expr::List(items, _) => {
                // cons list: (a, (b, (c, ())))
                let mut acc = self.m.constant(Const::Unit);
                for it in items.iter().rev() {
                    let head = self.lower_expr(g, it, env)?;
                    acc = self.m.apply_prim(g, Prim::MakeTuple, &[head, acc]);
                }
                acc
            }
            Expr::BinOp(op, a, b, _) => {
                let an = self.lower_expr(g, a, env)?;
                let bn = self.lower_expr(g, b, env)?;
                let p = match op {
                    BinOp::Add => Prim::Add,
                    BinOp::Sub => Prim::Sub,
                    BinOp::Mul => Prim::Mul,
                    BinOp::Div => Prim::Div,
                    BinOp::FloorDiv => Prim::FloorDiv,
                    BinOp::Mod => Prim::Mod,
                    BinOp::Pow => Prim::Pow,
                    BinOp::MatMul => Prim::MatMul,
                };
                self.m.apply_prim(g, p, &[an, bn])
            }
            Expr::Neg(a, _) => {
                let an = self.lower_expr(g, a, env)?;
                self.m.apply_prim(g, Prim::Neg, &[an])
            }
            Expr::Not(a, _) => {
                let an = self.lower_expr(g, a, env)?;
                self.m.apply_prim(g, Prim::Not, &[an])
            }
            Expr::Compare(op, a, b, _) => {
                let an = self.lower_expr(g, a, env)?;
                let bn = self.lower_expr(g, b, env)?;
                let p = match op {
                    CmpOp::Lt => Prim::Lt,
                    CmpOp::Gt => Prim::Gt,
                    CmpOp::Le => Prim::Le,
                    CmpOp::Ge => Prim::Ge,
                    CmpOp::Eq => Prim::Eq,
                    CmpOp::Ne => Prim::Ne,
                };
                self.m.apply_prim(g, p, &[an, bn])
            }
            Expr::And(a, b, _) => {
                // switch(a, thunk_b, thunk_False)()
                let an = self.lower_expr(g, a, env)?;
                let bt = self.expr_thunk(env, b, "and_rhs")?;
                let fe = Expr::Bool(false, e.line());
                let ft = self.expr_thunk(env, &fe, "and_false")?;
                let sel = self.m.apply_prim(g, Prim::Switch, &[an, bt, ft]);
                self.m.apply(g, vec![sel])
            }
            Expr::Or(a, b, _) => {
                let an = self.lower_expr(g, a, env)?;
                let te = Expr::Bool(true, e.line());
                let tt = self.expr_thunk(env, &te, "or_true")?;
                let bt = self.expr_thunk(env, b, "or_rhs")?;
                let sel = self.m.apply_prim(g, Prim::Switch, &[an, tt, bt]);
                self.m.apply(g, vec![sel])
            }
            Expr::IfExp(c, t, f, _) => {
                let cn = self.lower_expr(g, c, env)?;
                let tt = self.expr_thunk(env, t, "ternary_true")?;
                let ft = self.expr_thunk(env, f, "ternary_false")?;
                let sel = self.m.apply_prim(g, Prim::Switch, &[cn, tt, ft]);
                self.m.apply(g, vec![sel])
            }
            Expr::Call(f, args, _) => {
                let fnode = self.lower_expr(g, f, env)?;
                let mut inputs = vec![fnode];
                for a in args {
                    inputs.push(self.lower_expr(g, a, env)?);
                }
                self.m.apply(g, inputs)
            }
            Expr::Index(x, i, _) => {
                let xn = self.lower_expr(g, x, env)?;
                let in_ = self.lower_expr(g, i, env)?;
                self.m.apply_prim(g, Prim::TupleGetItem, &[xn, in_])
            }
            Expr::Lambda(params, body, line) => {
                let name = self.fresh_name("lambda");
                let stmts = vec![Stmt::Return(Some((**body).clone()), *line)];
                let lg = self.lower_function(&name, params, &stmts, env)?;
                self.m.graph_constant(lg)
            }
        })
    }
}

/// Builtin function table: Python-level names → primitives.
fn builtin(name: &str) -> Option<Prim> {
    match name {
        "print" => Some(Prim::Print),
        "len" => Some(Prim::TupleLen),
        _ => Prim::by_name(name),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::print_graph;
    use crate::parser::parse::parse_module;

    fn lower(src: &str) -> (Module, HashMap<String, GraphId>) {
        let mut m = Module::new();
        let ast = parse_module(src).unwrap();
        let graphs = lower_module(&mut m, &ast).unwrap();
        m.validate().unwrap();
        (m, graphs)
    }

    #[test]
    fn simple_function_lowering() {
        let (m, gs) = lower("def f(x):\n    return x ** 3\n");
        let f = gs["f"];
        let order = m.topo_order(f);
        assert_eq!(order.len(), 1);
        assert!(m.is_apply_of(order[0], Prim::Pow));
    }

    #[test]
    fn nested_function_captures_free_variable() {
        let (m, gs) = lower("def f(x):\n    def g(y):\n        return y + x\n    return g(2)\n");
        let f = gs["f"];
        let nested = m.reachable_graphs(f);
        assert_eq!(nested.len(), 2);
        let g = nested.into_iter().find(|&h| h != f).unwrap();
        let fvs = m.free_variables_total(g);
        assert_eq!(fvs.len(), 1);
        assert_eq!(m.node(fvs[0]).debug_name.as_deref(), Some("x"));
    }

    #[test]
    fn recursion_sees_own_name() {
        let (m, gs) = lower(
            "def fact(n):\n    return 1 if n <= 1 else n * fact(n - 1)\n",
        );
        let f = gs["fact"];
        // some reachable graph applies the fact constant again
        let all = m.reachable_graphs(f);
        assert!(all.len() >= 3, "ternary thunks present");
        let txt = print_graph(&m, f, true);
        assert!(txt.contains("@fact"), "{txt}");
    }

    #[test]
    fn while_lowering_structure() {
        let (m, gs) = lower(
            "def f(n):\n    s = 0\n    i = 0\n    while i < n:\n        s = s + i\n        i = i + 1\n    return s\n",
        );
        let f = gs["f"];
        let txt = print_graph(&m, f, true);
        assert!(txt.contains("while_header"), "{txt}");
        assert!(txt.contains("switch("), "{txt}");
        // header should have two params (s, i)
        let header = m
            .graph_ids()
            .find(|&h| m.graph(h).name.starts_with("while_header"))
            .unwrap();
        assert_eq!(m.graph(header).params.len(), 2);
    }

    #[test]
    fn for_range_desugars_to_while() {
        let (m, gs) = lower(
            "def f(n):\n    s = 0\n    for i in range(n):\n        s = s + i\n    return s\n",
        );
        let txt = print_graph(&m, gs["f"], true);
        assert!(txt.contains("while_header"), "{txt}");
        assert!(txt.contains("lt("), "{txt}");
    }

    #[test]
    fn if_with_continuation_params() {
        let (m, gs) = lower(
            "def f(x):\n    if x > 0:\n        y = x\n    else:\n        y = -x\n    return y * 2\n",
        );
        let f = gs["f"];
        let k = m
            .graph_ids()
            .find(|&h| m.graph(h).name.starts_with("if_cont"))
            .expect("continuation graph exists");
        // y is merged → continuation takes one parameter
        assert_eq!(m.graph(k).params.len(), 1);
        assert_eq!(m.node(m.graph(k).params[0]).debug_name.as_deref(), Some("y"));
        let _ = f;
    }

    #[test]
    fn early_return_pattern() {
        let (m, gs) = lower(
            "def f(x):\n    if x < 0:\n        return 0\n    return x\n",
        );
        let txt = print_graph(&m, gs["f"], true);
        assert!(txt.contains("if_true"), "{txt}");
        // fallthrough branch continues to the rest via if_false thunk
        assert!(txt.contains("if_false"), "{txt}");
    }

    #[test]
    fn undefined_name_reports_line() {
        let mut m = Module::new();
        let ast = parse_module("def f(x):\n    return x + zzz\n").unwrap();
        let err = lower_module(&mut m, &ast).unwrap_err();
        assert!(err.message.contains("zzz"));
        assert_eq!(err.line, 2);
    }

    #[test]
    fn top_level_statement_rejected() {
        let mut m = Module::new();
        let ast = parse_module("x = 5\n").unwrap();
        assert!(lower_module(&mut m, &ast).is_err());
    }

    #[test]
    fn grad_macro_lowered_with_forward_reference() {
        let (m, gs) =
            lower("def df(x):\n    return grad(square)(x)\n\ndef square(x):\n    return x * x\n");
        let df = gs["df"];
        // df's body contains an apply whose callee is an apply of the grad macro
        let order = m.topo_order(df);
        let has_macro = order.iter().any(|&n| {
            m.node(n).inputs().iter().any(|&i| {
                matches!(m.node(i).constant(), Some(Const::Macro(MacroOp::Grad)))
            })
        });
        assert!(has_macro, "{}", print_graph(&m, df, true));
    }

    #[test]
    fn destructuring_lowers_to_getitems() {
        let (m, gs) = lower("def f(t):\n    a, b = t\n    return a + b\n");
        let f = gs["f"];
        let order = m.topo_order(f);
        let getitems = order.iter().filter(|&&n| m.is_apply_of(n, Prim::TupleGetItem)).count();
        assert_eq!(getitems, 2);
    }

    #[test]
    fn list_literal_is_cons_chain() {
        let (m, gs) = lower("def f():\n    return [1, 2]\n");
        let f = gs["f"];
        let order = m.topo_order(f);
        let tuples = order.iter().filter(|&&n| m.is_apply_of(n, Prim::MakeTuple)).count();
        assert_eq!(tuples, 2); // (1, (2, ()))
    }

    #[test]
    fn short_circuit_becomes_switch_thunks() {
        let (m, gs) = lower("def f(n):\n    return n <= 1 or f(n - 1)\n");
        let txt = print_graph(&m, gs["f"], true);
        assert!(txt.contains("or_rhs"), "{txt}");
        assert!(txt.contains("switch("), "{txt}");
    }

    #[test]
    fn lambda_lowering() {
        let (m, gs) = lower("def f(x):\n    g = lambda y: y * x\n    return g(3)\n");
        let f = gs["f"];
        let lam = m
            .graph_ids()
            .find(|&h| m.graph(h).name.starts_with("lambda"))
            .unwrap();
        assert_eq!(m.free_variables_total(lam).len(), 1);
        let _ = f;
    }
}

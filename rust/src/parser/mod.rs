//! The Python-subset front end (§4.1).
//!
//! "Users can write models in a subset of Python 3.6 and have them compiled
//! to our IR." The pipeline is [`lexer`] → [`parse`] → [`lower`]; mutation
//! statements are rejected with targeted errors, and everything else —
//! nested functions, lambdas, conditionals, loops, recursion, higher-order
//! functions — lowers onto the purely functional graph IR.

pub mod ast;
pub mod lexer;
pub mod lower;
pub mod parse;

pub use lower::{compile_source, lower_module, LowerError};
pub use parse::{parse_module, ParseError};

//! Recursive-descent parser for the Python subset (§4.1).

use super::ast::{assigned_names, BinOp, CmpOp, Expr, Stmt};
use super::lexer::{lex, Tok, Token};
use std::fmt;

/// Parse error with source position.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub message: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at {}:{}: {}", self.line, self.col, self.message)
    }
}

impl std::error::Error for ParseError {}

type PResult<T> = Result<T, ParseError>;

/// Parse a full module (a sequence of statements, usually `def`s).
pub fn parse_module(source: &str) -> PResult<Vec<Stmt>> {
    let tokens = lex(source).map_err(|e| ParseError { message: e.message, line: e.line, col: e.col })?;
    let mut p = Parser { tokens, pos: 0 };
    let mut stmts = Vec::new();
    p.skip_newlines();
    while !p.at(&Tok::Eof) {
        stmts.push(p.statement()?);
        p.skip_newlines();
    }
    // sanity: duplicate top-level definitions are confusing — reject early
    let names = assigned_names(&stmts);
    let mut seen = std::collections::HashSet::new();
    for n in &names {
        if !seen.insert(n) {
            // rebinding at top level is allowed in Python but almost always a
            // bug in a pure module of defs; we allow it silently for assigns
            // but this hook is where a lint would go.
        }
    }
    Ok(stmts)
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Token {
        &self.tokens[self.pos.min(self.tokens.len() - 1)]
    }

    fn peek2(&self) -> &Tok {
        &self.tokens[(self.pos + 1).min(self.tokens.len() - 1)].kind
    }

    fn at(&self, kind: &Tok) -> bool {
        &self.peek().kind == kind
    }

    fn bump(&mut self) -> Token {
        let t = self.tokens[self.pos.min(self.tokens.len() - 1)].clone();
        self.pos += 1;
        t
    }

    fn err<T>(&self, msg: impl Into<String>) -> PResult<T> {
        let t = self.peek();
        Err(ParseError { message: msg.into(), line: t.line, col: t.col })
    }

    fn expect(&mut self, kind: Tok) -> PResult<Token> {
        if self.at(&kind) {
            Ok(self.bump())
        } else {
            self.err(format!("expected {:?}, found {:?}", kind, self.peek().kind))
        }
    }

    fn skip_newlines(&mut self) {
        while self.at(&Tok::Newline) {
            self.bump();
        }
    }

    // ---- statements -------------------------------------------------------

    fn statement(&mut self) -> PResult<Stmt> {
        let line = self.peek().line;
        match &self.peek().kind {
            Tok::Def => self.funcdef(),
            Tok::Return => {
                self.bump();
                let value = if self.at(&Tok::Newline) { None } else { Some(self.expr()?) };
                self.expect(Tok::Newline)?;
                Ok(Stmt::Return(value, line))
            }
            Tok::If => self.if_stmt(),
            Tok::While => {
                self.bump();
                let cond = self.expr()?;
                self.expect(Tok::Colon)?;
                let body = self.block()?;
                Ok(Stmt::While { cond, body, line })
            }
            Tok::For => self.for_stmt(),
            Tok::Pass => {
                self.bump();
                self.expect(Tok::Newline)?;
                Ok(Stmt::Pass(line))
            }
            Tok::Name(_) => {
                // Could be: assignment, destructuring, aug-assign (rejected),
                // index-assign (rejected), or a bare expression.
                self.assign_or_expr()
            }
            _ => {
                let e = self.expr()?;
                self.expect(Tok::Newline)?;
                Ok(Stmt::ExprStmt(e, line))
            }
        }
    }

    fn funcdef(&mut self) -> PResult<Stmt> {
        let line = self.peek().line;
        self.expect(Tok::Def)?;
        let name = match self.bump().kind {
            Tok::Name(n) => n,
            other => return self.err(format!("expected function name, found {other:?}")),
        };
        self.expect(Tok::LParen)?;
        let mut params = Vec::new();
        while !self.at(&Tok::RParen) {
            match self.bump().kind {
                Tok::Name(n) => params.push(n),
                other => return self.err(format!("expected parameter name, found {other:?}")),
            }
            if self.at(&Tok::Comma) {
                self.bump();
            } else {
                break;
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::Colon)?;
        let body = self.block()?;
        Ok(Stmt::FuncDef { name, params, body, line })
    }

    fn if_stmt(&mut self) -> PResult<Stmt> {
        let line = self.peek().line;
        self.bump(); // if / elif
        let cond = self.expr()?;
        self.expect(Tok::Colon)?;
        let then = self.block()?;
        let orelse = if self.at(&Tok::Elif) {
            vec![self.if_stmt()?]
        } else if self.at(&Tok::Else) {
            self.bump();
            self.expect(Tok::Colon)?;
            self.block()?
        } else {
            Vec::new()
        };
        Ok(Stmt::If { cond, then, orelse, line })
    }

    fn for_stmt(&mut self) -> PResult<Stmt> {
        let line = self.peek().line;
        self.expect(Tok::For)?;
        let var = match self.bump().kind {
            Tok::Name(n) => n,
            other => return self.err(format!("expected loop variable, found {other:?}")),
        };
        self.expect(Tok::In)?;
        // only `range(expr)` is supported
        match self.bump().kind {
            Tok::Name(n) if n == "range" => {}
            other => {
                return self.err(format!(
                    "only `for v in range(n)` loops are supported, found iterator {other:?}"
                ))
            }
        }
        self.expect(Tok::LParen)?;
        let count = self.expr()?;
        self.expect(Tok::RParen)?;
        self.expect(Tok::Colon)?;
        let body = self.block()?;
        Ok(Stmt::ForRange { var, count, body, line })
    }

    fn assign_or_expr(&mut self) -> PResult<Stmt> {
        let line = self.peek().line;
        // Lookahead for `name = `, `name, name = `, `name += `, `name[ ... ] =`.
        let start = self.pos;
        // Try to parse a target list of names.
        let mut targets = Vec::new();
        loop {
            match &self.peek().kind {
                Tok::Name(n) => {
                    let n = n.clone();
                    match self.peek2() {
                        Tok::Assign | Tok::Comma => {
                            targets.push(n);
                            self.bump();
                            if self.at(&Tok::Comma) {
                                self.bump();
                                continue;
                            }
                            break;
                        }
                        Tok::AugAssign(op) => {
                            let op = op.clone();
                            return self.err(format!(
                                "augmented assignment `{n} {op} ...` implies mutation, which \
                                 Myia forbids (§4.1); write `{n} = {n} {} ...` instead",
                                &op[..1]
                            ));
                        }
                        _ => {
                            targets.clear();
                            self.pos = start;
                            break;
                        }
                    }
                }
                _ => {
                    targets.clear();
                    self.pos = start;
                    break;
                }
            }
        }
        if !targets.is_empty() {
            self.expect(Tok::Assign)?;
            let value = self.expr()?;
            self.expect(Tok::Newline)?;
            return Ok(Stmt::Assign { targets, value, line });
        }
        // Not a plain assignment: parse an expression, then check for the
        // forbidden `x[i] = v` form.
        let e = self.expr()?;
        if self.at(&Tok::Assign) {
            if matches!(e, Expr::Index(..)) {
                return self.err(
                    "index assignment `x[i] = v` implies mutation, which Myia forbids (§4.1); \
                     build a new tuple instead",
                );
            }
            return self.err("invalid assignment target");
        }
        self.expect(Tok::Newline)?;
        Ok(Stmt::ExprStmt(e, line))
    }

    fn block(&mut self) -> PResult<Vec<Stmt>> {
        self.expect(Tok::Newline)?;
        self.expect(Tok::Indent)?;
        let mut stmts = Vec::new();
        self.skip_newlines();
        while !self.at(&Tok::Dedent) && !self.at(&Tok::Eof) {
            stmts.push(self.statement()?);
            self.skip_newlines();
        }
        self.expect(Tok::Dedent)?;
        if stmts.is_empty() {
            return self.err("empty block");
        }
        Ok(stmts)
    }

    // ---- expressions (precedence climbing) ---------------------------------

    fn expr(&mut self) -> PResult<Expr> {
        self.ternary()
    }

    fn ternary(&mut self) -> PResult<Expr> {
        let line = self.peek().line;
        let body = self.or_expr()?;
        if self.at(&Tok::If) {
            self.bump();
            let cond = self.or_expr()?;
            self.expect(Tok::Else)?;
            let orelse = self.ternary()?;
            Ok(Expr::IfExp(Box::new(cond), Box::new(body), Box::new(orelse), line))
        } else {
            Ok(body)
        }
    }

    fn or_expr(&mut self) -> PResult<Expr> {
        let mut lhs = self.and_expr()?;
        while self.at(&Tok::Or) {
            let line = self.bump().line;
            let rhs = self.and_expr()?;
            lhs = Expr::Or(Box::new(lhs), Box::new(rhs), line);
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> PResult<Expr> {
        let mut lhs = self.not_expr()?;
        while self.at(&Tok::And) {
            let line = self.bump().line;
            let rhs = self.not_expr()?;
            lhs = Expr::And(Box::new(lhs), Box::new(rhs), line);
        }
        Ok(lhs)
    }

    fn not_expr(&mut self) -> PResult<Expr> {
        if self.at(&Tok::Not) {
            let line = self.bump().line;
            let e = self.not_expr()?;
            Ok(Expr::Not(Box::new(e), line))
        } else {
            self.comparison()
        }
    }

    fn comparison(&mut self) -> PResult<Expr> {
        let lhs = self.arith()?;
        let op = match self.peek().kind {
            Tok::Lt => CmpOp::Lt,
            Tok::Gt => CmpOp::Gt,
            Tok::Le => CmpOp::Le,
            Tok::Ge => CmpOp::Ge,
            Tok::EqEq => CmpOp::Eq,
            Tok::NotEq => CmpOp::Ne,
            _ => return Ok(lhs),
        };
        let line = self.bump().line;
        let rhs = self.arith()?;
        Ok(Expr::Compare(op, Box::new(lhs), Box::new(rhs), line))
    }

    fn arith(&mut self) -> PResult<Expr> {
        let mut lhs = self.term()?;
        loop {
            let op = match self.peek().kind {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => return Ok(lhs),
            };
            let line = self.bump().line;
            let rhs = self.term()?;
            lhs = Expr::BinOp(op, Box::new(lhs), Box::new(rhs), line);
        }
    }

    fn term(&mut self) -> PResult<Expr> {
        let mut lhs = self.unary()?;
        loop {
            let op = match self.peek().kind {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::DoubleSlash => BinOp::FloorDiv,
                Tok::Percent => BinOp::Mod,
                Tok::At => BinOp::MatMul,
                _ => return Ok(lhs),
            };
            let line = self.bump().line;
            let rhs = self.unary()?;
            lhs = Expr::BinOp(op, Box::new(lhs), Box::new(rhs), line);
        }
    }

    fn unary(&mut self) -> PResult<Expr> {
        match self.peek().kind {
            Tok::Minus => {
                let line = self.bump().line;
                let e = self.unary()?;
                Ok(Expr::Neg(Box::new(e), line))
            }
            Tok::Plus => {
                self.bump();
                self.unary()
            }
            _ => self.power(),
        }
    }

    fn power(&mut self) -> PResult<Expr> {
        let base = self.postfix()?;
        if self.at(&Tok::DoubleStar) {
            let line = self.bump().line;
            let exp = self.unary()?; // right-assoc, binds tighter than unary minus on the left
            Ok(Expr::BinOp(BinOp::Pow, Box::new(base), Box::new(exp), line))
        } else {
            Ok(base)
        }
    }

    fn postfix(&mut self) -> PResult<Expr> {
        let mut e = self.atom()?;
        loop {
            match self.peek().kind {
                Tok::LParen => {
                    let line = self.bump().line;
                    let mut args = Vec::new();
                    while !self.at(&Tok::RParen) {
                        args.push(self.expr()?);
                        if self.at(&Tok::Comma) {
                            self.bump();
                        } else {
                            break;
                        }
                    }
                    self.expect(Tok::RParen)?;
                    e = Expr::Call(Box::new(e), args, line);
                }
                Tok::LBracket => {
                    let line = self.bump().line;
                    let idx = self.expr()?;
                    self.expect(Tok::RBracket)?;
                    e = Expr::Index(Box::new(e), Box::new(idx), line);
                }
                Tok::Dot => {
                    return self.err(
                        "attribute access is not supported in the Myia subset; \
                         use the functional builtins instead",
                    );
                }
                _ => return Ok(e),
            }
        }
    }

    fn atom(&mut self) -> PResult<Expr> {
        let line = self.peek().line;
        match self.bump().kind {
            Tok::Int(v) => Ok(Expr::Int(v, line)),
            Tok::Float(v) => Ok(Expr::Float(v, line)),
            Tok::True => Ok(Expr::Bool(true, line)),
            Tok::False => Ok(Expr::Bool(false, line)),
            Tok::None_ => Ok(Expr::NoneLit(line)),
            Tok::Str(s) => Ok(Expr::Str(s, line)),
            Tok::Name(n) => Ok(Expr::Name(n, line)),
            Tok::Lambda => {
                let mut params = Vec::new();
                while !self.at(&Tok::Colon) {
                    match self.bump().kind {
                        Tok::Name(n) => params.push(n),
                        other => return self.err(format!("expected lambda parameter, found {other:?}")),
                    }
                    if self.at(&Tok::Comma) {
                        self.bump();
                    }
                }
                self.expect(Tok::Colon)?;
                let body = self.expr()?;
                Ok(Expr::Lambda(params, Box::new(body), line))
            }
            Tok::LParen => {
                if self.at(&Tok::RParen) {
                    self.bump();
                    return Ok(Expr::Tuple(Vec::new(), line));
                }
                let first = self.expr()?;
                if self.at(&Tok::Comma) {
                    let mut items = vec![first];
                    while self.at(&Tok::Comma) {
                        self.bump();
                        if self.at(&Tok::RParen) {
                            break;
                        }
                        items.push(self.expr()?);
                    }
                    self.expect(Tok::RParen)?;
                    Ok(Expr::Tuple(items, line))
                } else {
                    self.expect(Tok::RParen)?;
                    Ok(first)
                }
            }
            Tok::LBracket => {
                let mut items = Vec::new();
                while !self.at(&Tok::RBracket) {
                    items.push(self.expr()?);
                    if self.at(&Tok::Comma) {
                        self.bump();
                    } else {
                        break;
                    }
                }
                self.expect(Tok::RBracket)?;
                Ok(Expr::List(items, line))
            }
            other => {
                self.pos -= 1;
                self.err(format!("unexpected token {other:?} in expression"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> Vec<Stmt> {
        parse_module(src).unwrap()
    }

    #[test]
    fn simple_function() {
        let m = parse("def f(x):\n    return x ** 3\n");
        assert_eq!(m.len(), 1);
        match &m[0] {
            Stmt::FuncDef { name, params, body, .. } => {
                assert_eq!(name, "f");
                assert_eq!(params, &["x".to_string()]);
                assert!(matches!(&body[0], Stmt::Return(Some(Expr::BinOp(BinOp::Pow, ..)), _)));
            }
            other => panic!("expected funcdef, got {other:?}"),
        }
    }

    #[test]
    fn precedence() {
        let m = parse("x = 1 + 2 * 3 ** 2\n");
        match &m[0] {
            Stmt::Assign { value, .. } => match value {
                Expr::BinOp(BinOp::Add, _, rhs, _) => match rhs.as_ref() {
                    Expr::BinOp(BinOp::Mul, _, rhs2, _) => {
                        assert!(matches!(rhs2.as_ref(), Expr::BinOp(BinOp::Pow, ..)));
                    }
                    other => panic!("expected mul, got {other:?}"),
                },
                other => panic!("expected add at top, got {other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unary_minus_and_pow() {
        // -x ** 2 parses as -(x ** 2) in Python
        let m = parse("y = -x ** 2\n");
        match &m[0] {
            Stmt::Assign { value: Expr::Neg(inner, _), .. } => {
                assert!(matches!(inner.as_ref(), Expr::BinOp(BinOp::Pow, ..)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn if_elif_else() {
        let m = parse("if a:\n    x = 1\nelif b:\n    x = 2\nelse:\n    x = 3\n");
        match &m[0] {
            Stmt::If { orelse, .. } => {
                assert_eq!(orelse.len(), 1);
                assert!(matches!(&orelse[0], Stmt::If { orelse: o2, .. } if o2.len() == 1));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn while_and_for() {
        let m = parse("while x < 10:\n    x = x + 1\n");
        assert!(matches!(&m[0], Stmt::While { .. }));
        let m = parse("for i in range(10):\n    s = s + i\n");
        assert!(matches!(&m[0], Stmt::ForRange { .. }));
        assert!(parse_module("for x in items:\n    pass\n").is_err());
    }

    #[test]
    fn destructuring_assignment() {
        let m = parse("a, b = f(x)\n");
        match &m[0] {
            Stmt::Assign { targets, .. } => assert_eq!(targets, &["a".to_string(), "b".to_string()]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn mutation_rejected_with_targeted_errors() {
        let e = parse_module("x += 1\n").unwrap_err();
        assert!(e.message.contains("augmented assignment"), "{e}");
        assert!(e.message.contains("forbids"), "{e}");
        let e = parse_module("x[0] = 5\n").unwrap_err();
        assert!(e.message.contains("index assignment"), "{e}");
    }

    #[test]
    fn attribute_access_rejected() {
        let e = parse_module("y = x.T\n").unwrap_err();
        assert!(e.message.contains("attribute access"), "{e}");
    }

    #[test]
    fn lambda_and_call() {
        let m = parse("f = lambda x, y: x + y\nz = f(1, 2)\n");
        assert!(matches!(&m[0], Stmt::Assign { value: Expr::Lambda(p, _, _), .. } if p.len() == 2));
        assert!(matches!(&m[1], Stmt::Assign { value: Expr::Call(_, args, _), .. } if args.len() == 2));
    }

    #[test]
    fn tuples_lists_indexing() {
        let m = parse("t = (1, 2, 3)\nl = [1, 2]\nx = t[0]\ne = ()\n");
        assert!(matches!(&m[0], Stmt::Assign { value: Expr::Tuple(v, _), .. } if v.len() == 3));
        assert!(matches!(&m[1], Stmt::Assign { value: Expr::List(v, _), .. } if v.len() == 2));
        assert!(matches!(&m[2], Stmt::Assign { value: Expr::Index(..), .. }));
        assert!(matches!(&m[3], Stmt::Assign { value: Expr::Tuple(v, _), .. } if v.is_empty()));
    }

    #[test]
    fn short_circuit_and_ternary() {
        let m = parse("x = a and b or not c\ny = 1 if c else 2\n");
        assert!(matches!(&m[0], Stmt::Assign { value: Expr::Or(..), .. }));
        assert!(matches!(&m[1], Stmt::Assign { value: Expr::IfExp(..), .. }));
    }

    #[test]
    fn nested_def() {
        let m = parse("def f(x):\n    def g(y):\n        return y + x\n    return g(3)\n");
        match &m[0] {
            Stmt::FuncDef { body, .. } => {
                assert!(matches!(&body[0], Stmt::FuncDef { name, .. } if name == "g"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn empty_block_rejected() {
        assert!(parse_module("def f(x):\n    pass\n").is_ok());
        assert!(parse_module("def f(x):\nreturn 1\n").is_err());
    }

    #[test]
    fn matmul_operator() {
        let m = parse("c = a @ b\n");
        assert!(matches!(&m[0], Stmt::Assign { value: Expr::BinOp(BinOp::MatMul, ..), .. }));
    }
}

//! The abstract interpreter behind type/shape inference.

use super::AType;
use crate::ir::{analyze, Const, GraphId, Module, NodeId, Prim};
use crate::tensor::{ops::broadcast_shapes, DType};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, HashSet};

/// Infer the result type of calling `g` on arguments of the given types.
/// Raises an error for definite type/shape mismatches (§4.2: eager errors).
pub fn infer_call(m: &Module, g: GraphId, args: &[AType]) -> Result<AType> {
    let mut inf = Inferrer::new(m);
    inf.call_graph(g, args.to_vec())
}

/// Inference engine with per-signature memoization and recursion widening.
pub struct Inferrer<'m> {
    m: &'m Module,
    /// (graph, arg signature) → result (memo; polyvariant specialization).
    memo: HashMap<(GraphId, Vec<AType>), AType>,
    /// calls currently on the stack (recursion detection).
    pending: HashSet<(GraphId, Vec<AType>)>,
    /// inferred types of nodes (free variables of nested graphs look here).
    node_types: HashMap<NodeId, AType>,
}

impl<'m> Inferrer<'m> {
    pub fn new(m: &'m Module) -> Inferrer<'m> {
        Inferrer { m, memo: HashMap::new(), pending: HashSet::new(), node_types: HashMap::new() }
    }

    pub fn call_graph(&mut self, g: GraphId, args: Vec<AType>) -> Result<AType> {
        let params = self.m.graph(g).params.clone();
        if params.len() != args.len() {
            bail!(
                "`{}` expects {} arguments, got {}",
                self.m.graph(g).name,
                params.len(),
                args.len()
            );
        }
        let key = (g, args.clone());
        if let Some(t) = self.memo.get(&key) {
            return Ok(t.clone());
        }
        if self.pending.contains(&key) {
            // Recursive call: widen. A second pass refines via the memo.
            return Ok(AType::Any);
        }
        self.pending.insert(key.clone());
        for (p, a) in params.iter().zip(args.iter()) {
            // Join with any previous binding (polyvariance across contexts
            // is approximated by widening shared node types).
            let t = match self.node_types.get(p) {
                Some(prev) => prev.join(a),
                None => a.clone(),
            };
            self.node_types.insert(*p, t);
        }
        let result = self.eval_graph(g);
        self.pending.remove(&key);
        let result = result?;
        self.memo.insert(key, result.clone());
        Ok(result)
    }

    fn eval_graph(&mut self, g: GraphId) -> Result<AType> {
        let analysis = analyze(self.m, g);
        for &n in analysis.order_of(g) {
            let t = self.eval_apply(n)?;
            let t = match self.node_types.get(&n) {
                Some(prev) => prev.join(&t),
                None => t,
            };
            self.node_types.insert(n, t);
        }
        let ret = self.m.graph(g).ret.ok_or_else(|| anyhow!("graph without return"))?;
        self.type_of(ret)
    }

    fn type_of(&mut self, n: NodeId) -> Result<AType> {
        if let Some(t) = self.node_types.get(&n) {
            return Ok(t.clone());
        }
        let node = self.m.node(n);
        if let Some(c) = node.constant() {
            return Ok(match c {
                Const::Unit => AType::Unit,
                Const::F64(_) => AType::F64,
                Const::I64(_) => AType::I64,
                Const::Bool(_) => AType::Bool,
                Const::Str(_) => AType::Str,
                Const::Key(_) => AType::Key,
                Const::ZeroT => AType::ZeroT,
                Const::Tensor(t) => AType::Tensor {
                    dtype: t.dtype(),
                    shape: t.shape().iter().map(|&d| Some(d)).collect(),
                },
                Const::Prim(p) => AType::Prim(*p),
                Const::Graph(h) => AType::Func(h.0),
                Const::Macro(_) => AType::Any,
                Const::Fused(_) => AType::Any,
            });
        }
        // Unbound parameter / free variable: unknown.
        Ok(AType::Any)
    }

    fn eval_apply(&mut self, n: NodeId) -> Result<AType> {
        let inputs = self.m.node(n).inputs().to_vec();
        let callee_t = self.type_of(inputs[0])?;
        let mut args = Vec::with_capacity(inputs.len() - 1);
        for &a in &inputs[1..] {
            args.push(self.type_of(a)?);
        }
        match callee_t {
            AType::Prim(p) => prim_rule(self.m, p, &inputs[1..], &args),
            AType::Func(gid) => self.call_graph(GraphId(gid), args),
            AType::FuncUnion(gids) => {
                // A switch over branch thunks: infer each and join (§4.2).
                let mut result: Option<AType> = None;
                for gid in gids {
                    let t = self.call_graph(GraphId(gid), args.clone())?;
                    result = Some(match result {
                        Some(prev) => prev.join(&t),
                        None => t,
                    });
                }
                Ok(result.unwrap_or(AType::Any))
            }
            AType::Any => Ok(AType::Any),
            other => bail!(
                "cannot call a value of type `{other}`{}",
                self.m
                    .node(inputs[0])
                    .debug_name
                    .as_ref()
                    .map(|n| format!(" (`{n}`)"))
                    .unwrap_or_default()
            ),
        }
    }
}

/// Result types of primitives, with eager shape checking.
fn prim_rule(m: &Module, p: Prim, arg_nodes: &[NodeId], args: &[AType]) -> Result<AType> {
    use Prim::*;
    if let Some(ar) = p.arity() {
        if args.len() != ar {
            bail!("`{p}` expects {ar} arguments, got {}", args.len());
        }
    }
    let any = args.iter().any(|a| matches!(a, AType::Any));
    Ok(match p {
        Add | Sub | Mul | Maximum | Minimum | Gadd => binary_numeric(p, &args[0], &args[1])?,
        Div => match binary_numeric(p, &args[0], &args[1])? {
            AType::I64 => AType::F64, // true division
            t => t,
        },
        Pow | Mod | FloorDiv => binary_numeric(p, &args[0], &args[1])?,
        Neg | Abs => args[0].clone(),
        Exp | Ln | Tanh | Sqrt | Sin | Cos | Relu | Sigmoid | Sign | Step => match &args[0] {
            t @ AType::Tensor { .. } => t.clone(),
            AType::I64 | AType::F64 => AType::F64,
            AType::Any => AType::Any,
            other => bail!("`{p}` expects a number or tensor, got {other}"),
        },
        Lt | Gt | Le | Ge | Eq | Ne => {
            if let (AType::Tensor { shape: s1, .. }, AType::Tensor { shape: s2, .. }) =
                (&args[0], &args[1])
            {
                let shape = broadcast_abstract(s1, s2)
                    .map_err(|e| anyhow!("in `{p}`: {e}"))?;
                AType::Tensor { dtype: DType::Bool, shape }
            } else if matches!(&args[0], AType::Tensor { .. })
                || matches!(&args[1], AType::Tensor { .. })
            {
                AType::Any
            } else {
                AType::Bool
            }
        }
        Not | BoolAnd | BoolOr | IsNil => AType::Bool,
        Switch => {
            if !any && !matches!(args[0], AType::Bool) {
                bail!("`switch` condition must be bool, got {}", args[0]);
            }
            args[1].join(&args[2])
        }
        MakeTuple => AType::Tuple(args.to_vec()),
        TupleGetItem => match (&args[0], m.node(arg_nodes[1]).constant()) {
            (AType::Tuple(items), Some(Const::I64(i))) => {
                let n = items.len() as i64;
                let idx = if *i < 0 { *i + n } else { *i };
                if idx < 0 || idx >= n {
                    bail!("tuple index {i} out of range for {}-tuple", items.len());
                }
                items[idx as usize].clone()
            }
            (AType::Tuple(_), _) | (AType::Any, _) | (AType::ZeroT, _) => AType::Any,
            (other, _) => bail!("indexing a non-tuple value of type {other}"),
        },
        TupleLen => AType::I64,
        TupleInject => AType::Any,
        NewEnv | EnvSetItem => AType::Env,
        EnvGetItem => AType::Any,
        ZerosLike | OnesLike => args[0].clone(),
        MatMul => matmul_rule(&args[0], &args[1])?,
        Transpose => match &args[0] {
            // Swaps the last two axes; leading axes are batch dimensions.
            AType::Tensor { dtype, shape } if shape.len() >= 2 => {
                let mut s = shape.clone();
                let r = s.len();
                s.swap(r - 2, r - 1);
                AType::Tensor { dtype: *dtype, shape: s }
            }
            t @ AType::Tensor { .. } => t.clone(),
            AType::Any => AType::Any,
            other => bail!("`transpose` expects a tensor, got {other}"),
        },
        Reshape | BroadcastTo | SumTo => match &args[0] {
            AType::Tensor { dtype, .. } => {
                // Shape known only if the tuple is constant — else unknown.
                AType::Tensor { dtype: *dtype, shape: vec![] }.widen_shape()
            }
            AType::Any => AType::Any,
            other => bail!("`{p}` expects a tensor, got {other}"),
        },
        ShapeOf => AType::Any,
        ReduceSum | ReduceMean => match &args[0] {
            AType::Tensor { dtype, .. } => AType::Tensor { dtype: *dtype, shape: vec![] },
            AType::F64 | AType::I64 | AType::Any => AType::Any,
            other => bail!("`{p}` expects a tensor, got {other}"),
        },
        SoftmaxLast | SumLastKeep => match &args[0] {
            t @ AType::Tensor { .. } => {
                if p == SumLastKeep {
                    if let AType::Tensor { dtype, shape } = t {
                        let mut s = shape.clone();
                        if let Some(last) = s.last_mut() {
                            *last = Some(1);
                        }
                        return Ok(AType::Tensor { dtype: *dtype, shape: s });
                    }
                }
                t.clone()
            }
            AType::Any => AType::Any,
            other => bail!("`{p}` expects a tensor, got {other}"),
        },
        Item => AType::F64,
        ScalarToTensor => AType::Tensor { dtype: DType::F64, shape: vec![] },
        CastF32 | CastF64 => match &args[0] {
            AType::Tensor { shape, .. } => AType::Tensor {
                dtype: if p == CastF32 { DType::F32 } else { DType::F64 },
                shape: shape.clone(),
            },
            _ => AType::Any,
        },
        Print => args[0].clone(),
        Raise => AType::Any,
        _ => AType::Any,
    })
}

impl AType {
    fn widen_shape(self) -> AType {
        match self {
            AType::Tensor { dtype, .. } => AType::Tensor { dtype, shape: vec![None] },
            t => t,
        }
    }
}

fn binary_numeric(p: Prim, a: &AType, b: &AType) -> Result<AType> {
    Ok(match (a, b) {
        (AType::Any, _) | (_, AType::Any) => AType::Any,
        (AType::ZeroT, x) | (x, AType::ZeroT) => x.clone(),
        (AType::Tensor { dtype: d1, shape: s1 }, AType::Tensor { dtype: d2, shape: s2 }) => {
            let shape = broadcast_abstract(s1, s2).map_err(|e| anyhow!("in `{p}`: {e}"))?;
            let dtype = if *d1 == DType::F64 || *d2 == DType::F64 {
                DType::F64
            } else if *d1 == DType::F32 || *d2 == DType::F32 {
                DType::F32
            } else {
                *d1
            };
            AType::Tensor { dtype, shape }
        }
        (t @ AType::Tensor { .. }, s) | (s, t @ AType::Tensor { .. }) if s.is_scalar_num() => {
            t.clone()
        }
        (AType::I64, AType::I64) => AType::I64,
        (x, y) if x.is_scalar_num() && y.is_scalar_num() => AType::F64,
        (AType::Tuple(x), AType::Tuple(y)) if p == Prim::Gadd && x.len() == y.len() => {
            AType::Tuple(x.iter().zip(y.iter()).map(|(a, b)| a.join(b)).collect())
        }
        (AType::Env, AType::Env) if p == Prim::Gadd => AType::Env,
        (x, y) => bail!("`{p}` cannot combine {x} and {y}"),
    })
}

fn matmul_rule(a: &AType, b: &AType) -> Result<AType> {
    match (a, b) {
        (AType::Any, _) | (_, AType::Any) => Ok(AType::Any),
        (AType::Tensor { dtype, shape: s1 }, AType::Tensor { shape: s2, .. }) => {
            if s1.len() == 2 && s2.len() == 2 {
                if let (Some(k1), Some(k2)) = (s1[1], s2[0]) {
                    if k1 != k2 {
                        bail!(
                            "matmul inner dimension mismatch: [?, {k1}] @ [{k2}, ?] \
                             (caught before execution — §4.2)"
                        );
                    }
                }
                Ok(AType::Tensor { dtype: *dtype, shape: vec![s1[0], s2[1]] })
            } else {
                Ok(AType::Tensor { dtype: *dtype, shape: vec![None] })
            }
        }
        (x, y) => bail!("matmul expects tensors, got {x} and {y}"),
    }
}

/// Abstract broadcasting: unknown dims unify with anything.
fn broadcast_abstract(
    a: &[Option<usize>],
    b: &[Option<usize>],
) -> std::result::Result<Vec<Option<usize>>, String> {
    // Fully known shapes reuse the concrete checker for identical errors.
    if a.iter().all(Option::is_some) && b.iter().all(Option::is_some) {
        let ca: Vec<usize> = a.iter().map(|d| d.unwrap()).collect();
        let cb: Vec<usize> = b.iter().map(|d| d.unwrap()).collect();
        return broadcast_shapes(&ca, &cb)
            .map(|s| s.into_iter().map(Some).collect())
            .map_err(|e| e.0);
    }
    let rank = a.len().max(b.len());
    let mut out = vec![None; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { Some(1) } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { Some(1) } else { b[i - (rank - b.len())] };
        out[i] = match (da, db) {
            (Some(1), d) | (d, Some(1)) => d,
            (Some(x), Some(y)) if x == y => Some(x),
            (Some(x), Some(y)) => return Err(format!("cannot broadcast dims {x} and {y}")),
            (None, d) | (d, None) => d,
        };
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::compile_source;

    fn infer(src: &str, entry: &str, args: &[AType]) -> Result<AType> {
        let mut m = Module::new();
        let graphs = compile_source(&mut m, src)?;
        infer_call(&m, graphs[entry], args)
    }

    fn t(shape: &[usize]) -> AType {
        AType::Tensor { dtype: DType::F64, shape: shape.iter().map(|&d| Some(d)).collect() }
    }

    #[test]
    fn scalar_inference() {
        let r = infer("def f(x):\n    return x * x + 1.0\n", "f", &[AType::F64]).unwrap();
        assert_eq!(r, AType::F64);
        let r = infer("def f(n):\n    return n + 1\n", "f", &[AType::I64]).unwrap();
        assert_eq!(r, AType::I64);
    }

    #[test]
    fn polymorphic_specialization() {
        // same function, two signatures (§4.2 polyvariance)
        let src = "def f(x):\n    return x + x\n";
        assert_eq!(infer(src, "f", &[AType::F64]).unwrap(), AType::F64);
        assert_eq!(infer(src, "f", &[t(&[3])]).unwrap(), t(&[3]));
    }

    #[test]
    fn matmul_shapes_propagate() {
        let src = "def f(a, b):\n    return matmul(a, b)\n";
        let r = infer(src, "f", &[t(&[2, 3]), t(&[3, 5])]).unwrap();
        assert_eq!(r, t(&[2, 5]));
    }

    #[test]
    fn shape_mismatch_caught_eagerly() {
        let src = "def f(a, b):\n    return matmul(a, b)\n";
        let e = infer(src, "f", &[t(&[2, 3]), t(&[4, 5])]).unwrap_err();
        assert!(format!("{e}").contains("inner dimension mismatch"), "{e}");
        let src = "def f(a, b):\n    return a + b\n";
        let e = infer(src, "f", &[t(&[2]), t(&[3])]).unwrap_err();
        assert!(format!("{e}").contains("broadcast"), "{e}");
    }

    #[test]
    fn conditionals_join_branches() {
        let src = "def f(x):\n    if x > 0.0:\n        return 1.0\n    else:\n        return 2\n";
        let r = infer(src, "f", &[AType::F64]).unwrap();
        assert_eq!(r, AType::F64); // join(f64, i64) = f64
    }

    #[test]
    fn recursion_converges() {
        let src = "def fact(n):\n    return 1 if n <= 1 else n * fact(n - 1)\n";
        let r = infer(src, "fact", &[AType::I64]).unwrap();
        // Any (widened) or i64 depending on join order — must not hang.
        assert!(matches!(r, AType::I64 | AType::Any), "{r}");
    }

    #[test]
    fn higher_order_functions_specialize() {
        let src = "\
def apply(f, x):
    return f(x)

def sq(t):
    return t * t

def main(x):
    return apply(sq, x)
";
        let r = infer(src, "main", &[AType::F64]).unwrap();
        assert_eq!(r, AType::F64);
    }

    #[test]
    fn calling_non_function_is_an_error() {
        let src = "def f(x):\n    y = 1.0\n    return y(x)\n";
        let e = infer(src, "f", &[AType::F64]).unwrap_err();
        assert!(format!("{e}").contains("cannot call"), "{e}");
    }

    #[test]
    fn tuple_types_tracked() {
        let src = "def f(x):\n    t = (x, x * 2.0, 3)\n    return t[2]\n";
        let r = infer(src, "f", &[AType::F64]).unwrap();
        assert_eq!(r, AType::I64);
        let src = "def f(x):\n    t = (x, 1)\n    return t[5]\n";
        let e = infer(src, "f", &[AType::F64]).unwrap_err();
        assert!(format!("{e}").contains("out of range"), "{e}");
    }

    #[test]
    fn arity_mismatch_eager() {
        let src = "\
def g(a, b):
    return a

def f(x):
    return g(x)
";
        let e = infer(src, "f", &[AType::F64]).unwrap_err();
        assert!(format!("{e}").contains("expects 2 arguments"), "{e}");
    }
}

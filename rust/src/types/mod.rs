//! Type and shape inference (§4.2).
//!
//! "When a Myia function is called, we use the types of the user-provided
//! arguments as a starting point for type inference, which allows us to
//! compile a specialized version of the function for these types. No type
//! annotations are required, even when using higher order functions."
//!
//! [`infer_call`] abstractly interprets a graph on abstract values: concrete
//! dtypes, tensor shapes with per-dimension unknowns, tuples, and function
//! values carried *precisely* (a graph reference plus the abstract values of
//! its free variables), so higher-order code and closures specialize per
//! call site (polyvariance). Recursion is handled by a pending-call set that
//! widens to `Any` and refines on a second pass — the fixpoint the paper
//! alludes to for recursive calls. Errors (shape mismatches, bad arities,
//! calling non-functions) surface *before* any tensor work happens: "it is
//! best to catch errors as early as possible".

mod infer;

pub use infer::{infer_call, Inferrer};

use crate::tensor::DType;
use crate::vm::Value;
use std::fmt;

/// Abstract values.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum AType {
    Unit,
    F64,
    I64,
    Bool,
    Str,
    Key,
    ZeroT,
    Env,
    /// Tensor with dtype and per-dimension shape (None = unknown dim).
    Tensor { dtype: DType, shape: Vec<Option<usize>> },
    Tuple(Vec<AType>),
    /// A function value: the graph plus abstract free-variable context is
    /// tracked by the inferrer; here we keep the graph id for diagnostics.
    Func(u32),
    /// One of several possible functions (a `switch` over branch thunks);
    /// calling it infers every member and joins the results.
    FuncUnion(Vec<u32>),
    /// A primitive as a value.
    Prim(crate::ir::Prim),
    /// Unknown (widened) — anything goes; checks are deferred to runtime.
    Any,
}

impl AType {
    /// Abstract value of a runtime value (call-site entry point of §4.2).
    pub fn of_value(v: &Value) -> AType {
        match v {
            Value::Unit => AType::Unit,
            Value::F64(_) => AType::F64,
            Value::I64(_) => AType::I64,
            Value::Bool(_) => AType::Bool,
            Value::Str(_) => AType::Str,
            Value::Key(_) => AType::Key,
            Value::ZeroT => AType::ZeroT,
            Value::Env(_) => AType::Env,
            Value::Tensor(t) => AType::Tensor {
                dtype: t.dtype(),
                shape: t.shape().iter().map(|&d| Some(d)).collect(),
            },
            Value::Tuple(items) => AType::Tuple(items.iter().map(AType::of_value).collect()),
            Value::Closure(_) | Value::Partial(_) => AType::Any,
            Value::Prim(p) => AType::Prim(*p),
            Value::Fused(_) => AType::Any,
        }
    }

    /// Is this a numeric scalar type?
    pub fn is_scalar_num(&self) -> bool {
        matches!(self, AType::F64 | AType::I64 | AType::Bool)
    }

    /// Does a value of abstract type `actual` satisfy this (expected) type?
    ///
    /// The admission check of the serving layer: `expected` is a compiled
    /// artifact's stored signature entry, `actual` is `AType::of_value` of an
    /// incoming argument. Acceptance is *structural* equality except that
    /// the expected side may be less precise: `Any` accepts everything, an
    /// unknown tensor dimension (`None`) accepts any extent, and `ZeroT`
    /// (the symbolic zero) is accepted wherever a numeric or tensor value is
    /// expected. An `actual` of `Any` is rejected — an admission check that
    /// cannot see the value's type must not vouch for it.
    pub fn accepts(&self, actual: &AType) -> bool {
        match (self, actual) {
            (AType::Any, _) => true,
            (_, AType::Any) => false,
            (AType::ZeroT, AType::ZeroT) => true,
            (AType::F64 | AType::I64 | AType::Tensor { .. }, AType::ZeroT) => true,
            (
                AType::Tensor { dtype: ed, shape: es },
                AType::Tensor { dtype: ad, shape: as_ },
            ) => {
                ed == ad
                    && es.len() == as_.len()
                    && es
                        .iter()
                        .zip(as_.iter())
                        .all(|(e, a)| e.is_none() || e == a)
            }
            (AType::Tuple(es), AType::Tuple(asv)) => {
                es.len() == asv.len() && es.iter().zip(asv.iter()).all(|(e, a)| e.accepts(a))
            }
            (e, a) => e == a,
        }
    }

    /// Least upper bound (widening join).
    pub fn join(&self, other: &AType) -> AType {
        if self == other {
            return self.clone();
        }
        match (self, other) {
            (AType::Any, x) | (x, AType::Any) => {
                let _ = x;
                AType::Any
            }
            (AType::ZeroT, x) | (x, AType::ZeroT) => x.clone(),
            (AType::F64, AType::I64) | (AType::I64, AType::F64) => AType::F64,
            (
                AType::Tensor { dtype: d1, shape: s1 },
                AType::Tensor { dtype: d2, shape: s2 },
            ) if d1 == d2 && s1.len() == s2.len() => AType::Tensor {
                dtype: *d1,
                shape: s1
                    .iter()
                    .zip(s2.iter())
                    .map(|(a, b)| if a == b { *a } else { None })
                    .collect(),
            },
            (AType::Tuple(a), AType::Tuple(b)) if a.len() == b.len() => {
                AType::Tuple(a.iter().zip(b.iter()).map(|(x, y)| x.join(y)).collect())
            }
            (AType::Func(a), AType::Func(b)) => AType::FuncUnion(vec![*a, *b]),
            (AType::FuncUnion(u), AType::Func(b)) | (AType::Func(b), AType::FuncUnion(u)) => {
                let mut u = u.clone();
                if !u.contains(b) {
                    u.push(*b);
                }
                AType::FuncUnion(u)
            }
            (AType::FuncUnion(a), AType::FuncUnion(b)) => {
                let mut u = a.clone();
                for g in b {
                    if !u.contains(g) {
                        u.push(*g);
                    }
                }
                AType::FuncUnion(u)
            }
            _ => AType::Any,
        }
    }
}

impl fmt::Display for AType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AType::Unit => write!(f, "None"),
            AType::F64 => write!(f, "f64"),
            AType::I64 => write!(f, "i64"),
            AType::Bool => write!(f, "bool"),
            AType::Str => write!(f, "str"),
            AType::Key => write!(f, "key"),
            AType::ZeroT => write!(f, "zero"),
            AType::Env => write!(f, "env"),
            AType::Tensor { dtype, shape } => {
                write!(f, "tensor<{dtype}>[")?;
                for (i, d) in shape.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    match d {
                        Some(d) => write!(f, "{d}")?,
                        None => write!(f, "?")?,
                    }
                }
                write!(f, "]")
            }
            AType::Tuple(items) => {
                write!(f, "(")?;
                for (i, t) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{t}")?;
                }
                write!(f, ")")
            }
            AType::Func(g) => write!(f, "fn@{g}"),
            AType::FuncUnion(gs) => write!(f, "fn@{gs:?}"),
            AType::Prim(p) => write!(f, "prim<{p}>"),
            AType::Any => write!(f, "any"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn of_value_roundtrip() {
        assert_eq!(AType::of_value(&Value::F64(1.0)), AType::F64);
        let t = Value::Tensor(Tensor::zeros(DType::F32, &[2, 3]));
        assert_eq!(
            AType::of_value(&t),
            AType::Tensor { dtype: DType::F32, shape: vec![Some(2), Some(3)] }
        );
        let tup = Value::tuple(vec![Value::I64(1), Value::Bool(true)]);
        assert_eq!(AType::of_value(&tup), AType::Tuple(vec![AType::I64, AType::Bool]));
    }

    #[test]
    fn join_widens() {
        assert_eq!(AType::F64.join(&AType::F64), AType::F64);
        assert_eq!(AType::F64.join(&AType::I64), AType::F64);
        assert_eq!(AType::F64.join(&AType::Str), AType::Any);
        let a = AType::Tensor { dtype: DType::F64, shape: vec![Some(2), Some(3)] };
        let b = AType::Tensor { dtype: DType::F64, shape: vec![Some(4), Some(3)] };
        assert_eq!(
            a.join(&b),
            AType::Tensor { dtype: DType::F64, shape: vec![None, Some(3)] }
        );
        assert_eq!(AType::ZeroT.join(&AType::F64), AType::F64);
    }

    #[test]
    fn accepts_is_structural_with_unknown_dims() {
        let exact = AType::Tensor { dtype: DType::F64, shape: vec![Some(2), Some(3)] };
        let loose = AType::Tensor { dtype: DType::F64, shape: vec![None, Some(3)] };
        let other = AType::Tensor { dtype: DType::F64, shape: vec![Some(4), Some(3)] };
        let f32_t = AType::Tensor { dtype: DType::F32, shape: vec![Some(2), Some(3)] };
        assert!(exact.accepts(&exact));
        assert!(loose.accepts(&exact));
        assert!(loose.accepts(&other));
        assert!(!exact.accepts(&other), "concrete dims must match");
        assert!(!exact.accepts(&f32_t), "dtype must match");
        assert!(!exact.accepts(&loose), "actual side must be concrete");
        // Scalars: exact kind match, no numeric coercion at admission.
        assert!(AType::F64.accepts(&AType::F64));
        assert!(!AType::F64.accepts(&AType::I64));
        assert!(!AType::F64.accepts(&AType::Str));
        // Any expected accepts all; Any actual is never vouched for.
        assert!(AType::Any.accepts(&AType::Str));
        assert!(!AType::F64.accepts(&AType::Any));
        // Symbolic zero rides wherever numbers/tensors are expected.
        assert!(AType::F64.accepts(&AType::ZeroT));
        assert!(exact.accepts(&AType::ZeroT));
        assert!(!AType::Str.accepts(&AType::ZeroT));
        // Tuples recurse.
        let tup_e = AType::Tuple(vec![AType::F64, loose.clone()]);
        let tup_a = AType::Tuple(vec![AType::F64, exact.clone()]);
        assert!(tup_e.accepts(&tup_a));
        assert!(!tup_e.accepts(&AType::Tuple(vec![AType::F64])));
    }

    #[test]
    fn display_forms() {
        let t = AType::Tensor { dtype: DType::F64, shape: vec![Some(2), None] };
        assert_eq!(format!("{t}"), "tensor<f64>[2, ?]");
        assert_eq!(format!("{}", AType::Tuple(vec![AType::F64, AType::Any])), "(f64, any)");
    }
}

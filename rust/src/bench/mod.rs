//! Micro-benchmark harness (criterion substitute).
//!
//! Criterion is not available offline, so the bench binaries (declared with
//! `harness = false`) use this module: warmup, adaptive iteration counts
//! targeting a fixed measurement window, and robust statistics (median,
//! p10/p90). Output is a fixed-width table plus a machine-readable CSV line
//! per benchmark (prefix `CSV,`) so EXPERIMENTS.md tables can be regenerated
//! by piping bench output through `grep ^CSV`.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Human-readable benchmark id, e.g. `oo_tape/size=64`.
    pub name: String,
    /// Median seconds per iteration.
    pub median: f64,
    /// 10th percentile seconds per iteration.
    pub p10: f64,
    /// 90th percentile seconds per iteration.
    pub p90: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl Sample {
    /// Nanoseconds per iteration (median).
    pub fn ns(&self) -> f64 {
        self.median * 1e9
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Warmup time per benchmark.
    pub warmup: Duration,
    /// Target measurement time per benchmark.
    pub measure: Duration,
    /// Number of timed batches (each batch is `iters_per_batch` calls).
    pub batches: usize,
    collected: Vec<Sample>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            batches: 20,
            collected: Vec::new(),
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    /// Quick harness for unit tests (short windows).
    pub fn fast() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            batches: 5,
            collected: Vec::new(),
        }
    }

    /// Time `f`, returning (and recording) the sample.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> Sample {
        // Warmup and per-call estimate.
        let start = Instant::now();
        let mut calls = 0usize;
        while start.elapsed() < self.warmup || calls == 0 {
            f();
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let per_call = start.elapsed().as_secs_f64() / calls as f64;

        // Choose batch size so each batch is ~measure/batches long.
        let batch_target = self.measure.as_secs_f64() / self.batches as f64;
        let iters_per_batch = ((batch_target / per_call.max(1e-12)) as usize).clamp(1, 10_000_000);

        let mut times = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / iters_per_batch as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
        let sample = Sample {
            name: name.to_string(),
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            iters: iters_per_batch * self.batches,
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            sample.name,
            fmt_time(sample.median),
            fmt_time(sample.p10),
            fmt_time(sample.p90),
            sample.iters
        );
        println!(
            "CSV,{},{:.6e},{:.6e},{:.6e},{}",
            sample.name, sample.median, sample.p10, sample.p90, sample.iters
        );
        self.collected.push(sample.clone());
        sample
    }

    /// All samples recorded so far.
    pub fn samples(&self) -> &[Sample] {
        &self.collected
    }

    /// Print the standard table header.
    pub fn header(title: &str) {
        println!("\n=== {title} ===");
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            "benchmark", "median", "p10", "p90", "iters"
        );
    }
}

/// Render a duration in adaptive units.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::fast();
        let s = b.bench("noop_loop", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(s.median > 0.0);
        assert!(s.p10 <= s.median && s.median <= s.p90 * 1.5);
        assert_eq!(b.samples().len(), 1);
    }

    #[test]
    fn ordering_detected() {
        // A 50x-heavier loop should measure meaningfully slower.
        let mut b = Bencher::fast();
        let fast = b.bench("fast", || {
            let mut acc = 0u64;
            for i in 0..20u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        let slow = b.bench("slow", || {
            let mut acc = 0u64;
            for i in 0..2000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(slow.median > fast.median);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }
}

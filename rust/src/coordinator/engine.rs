//! The compile/run split: [`Engine`] owns parsing, the transform pipeline
//! machinery and the artifact cache; [`Executable`] is the immutable,
//! `Send + Sync` product of a compile that any number of threads may call
//! concurrently.
//!
//! The paper's claim (§3.2, §4) is that source-transformation AD produces
//! adjoint programs that are *ordinary, closed IR* — a compiled function is
//! a pure artifact with no hidden mutable runtime coupling. The API enforces
//! that split: everything mutable (the sharded compile cache) lives in the
//! `Engine` behind interior synchronization, everything an `Executable`
//! holds is frozen at compile time, and per-call state lives on the stack of
//! whichever thread is calling.
//!
//! [`Engine::trace`] returns a [`Function`] handle whose chainable methods
//! (`.grad()`, `.value_and_grad()`, `.vmap()`, `.optimize(PassSet)`,
//! `.jit(Backend)`) assemble a transform [`Pipeline`]; [`Function::compile`]
//! runs it and caches the result under `(entry, pipeline fingerprint,
//! argument-type signature)`. `f.grad().grad().compile()` is second-order AD
//! with no `grad(grad(…))` string anywhere in user source — the transforms
//! compose because the adjoint program is ordinary IR (§3.2).
//!
//! Compilation itself runs as a DAG of memoized queries
//! ([`crate::query::QueryEngine`]): macro expansion, each pipeline stage,
//! typechecking and codegen are separate queries keyed by structural
//! fingerprints of their inputs, so [`Engine::update_source`] re-runs only
//! the queries an edit actually reaches (red-green revalidation). The
//! sharded artifact cache is the *hot tier* above the queries; a persistent
//! *disk tier* ([`crate::runtime::diskcache::DiskCache`], enabled by
//! `MYIA_CACHE_DIR` or [`Engine::with_cache_dir`]) lets a fresh process
//! start warm.

use crate::ad::expand_macros;
use crate::backend::Backend;
use crate::ir::{analyze, content_fingerprint, GraphId, Module};
use crate::opt::PassSet;
use crate::parser::compile_source;
use crate::query::{mix_fp, IrSnapshot, QueryEngine, QueryKind, QueryStatsSnapshot};
use crate::runtime::diskcache::{ArtifactKey, DiskCache, StoredArtifact, StoredMeta};
use crate::serve::metrics::{CacheCounters, CacheStats};
use crate::transform::{Pipeline, StageMetrics, Transform};
use crate::types::AType;
use crate::vm::{compile_program, Value, Vm};
use anyhow::{anyhow, Result};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Compile-time metrics (E1/E6/E7 read these).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Canonical spec of the pipeline that produced this artifact.
    pub pipeline: String,
    /// Per-transform timings and node counts, in execution order.
    pub stages: Vec<StageMetrics>,
    pub parse_lower_us: u128,
    pub expand_us: u128,
    pub optimize_us: u128,
    pub codegen_us: u128,
    pub nodes_after_lowering: usize,
    pub nodes_after_expand: usize,
    pub nodes_after_optimize: usize,
    pub graphs_after_optimize: usize,
    /// Source-level `grad`/`value_and_grad`/`jfwd` macros expanded.
    pub macros_expanded: usize,
    /// Total derivative order applied by `Grad`/`ValueAndGrad` pipeline
    /// stages (programmatic grads; disjoint from `macros_expanded`).
    pub grad_transforms: usize,
    pub opt_iterations: usize,
    pub xla_segments: usize,
}

/// One compile-cache entry. Lookups compare borrowed data so a cache hit
/// allocates nothing (no `name` clone, no key construction).
struct CacheEntry {
    fingerprint: u64,
    /// Deep structural fingerprint of the entry's callee closure at compile
    /// time: an `update_source` that reaches this entry changes the
    /// fingerprint and silently retires the entry (it stops matching).
    module_fp: u64,
    signature: Option<Vec<AType>>,
    compiled: Arc<Executable>,
}

/// Number of independent cache shards. Entry names hash onto shards, so
/// compiles of *different* entry points never contend on one lock, and a
/// long compile holds no lock at all (only the post-compile insert does).
const CACHE_SHARDS: usize = 8;

/// The sharded, `Mutex`-protected artifact cache.
struct ArtifactCache {
    shards: [Mutex<HashMap<String, Vec<CacheEntry>>>; CACHE_SHARDS],
}

impl ArtifactCache {
    fn new() -> ArtifactCache {
        ArtifactCache { shards: std::array::from_fn(|_| Mutex::new(HashMap::new())) }
    }

    fn shard(&self, name: &str) -> &Mutex<HashMap<String, Vec<CacheEntry>>> {
        let mut h = DefaultHasher::new();
        name.hash(&mut h);
        &self.shards[(h.finish() as usize) % CACHE_SHARDS]
    }
}

/// A compilation engine over one source module — the compile-time half of
/// the compile/run split.
///
/// [`Engine::module`] holds the *pristine* lowered IR: every compile works
/// on its own clone, so an `Optimize` stage in one pipeline can never leak
/// into another pipeline's artifact (or into the engine), and the cache key
/// honestly describes what each artifact was built from. The transformed IR
/// a pipeline produced lives in [`Executable::module`].
///
/// All compile entry points take `&self`: the artifact cache is sharded and
/// `Mutex`-protected internally, so one `Engine` can serve compile requests
/// from many threads (see the `concurrent_compiles_share_one_artifact`
/// test).
pub struct Engine {
    pub module: Module,
    pub graphs: HashMap<String, GraphId>,
    cache: ArtifactCache,
    /// Artifact-cache hit/miss telemetry, `Arc`-shared so a serving layer
    /// built on this engine can fold it into one metrics snapshot.
    stats: Arc<CacheCounters>,
    /// The memoized compilation-query engine (red-green revalidation).
    queries: QueryEngine,
    /// Optional persistent artifact tier (`MYIA_CACHE_DIR` /
    /// [`Engine::with_cache_dir`]). VM artifacts only — XLA executables hold
    /// process-local runtime handles that cannot be serialized.
    disk: Option<DiskCache>,
}

/// A compiled, executable entry point: the run-time half of the compile/run
/// split. Owns the transformed IR snapshot it was generated from
/// ([`Executable::entry`] indexes into it).
///
/// An `Executable` is immutable after compilation and `Send + Sync` — wrap
/// it in the `Arc` that [`Function::compile`] already returns and call it
/// from as many threads as you like; results are identical to sequential
/// execution (the language is purely functional, §3).
pub struct Executable {
    pub vm: Vm,
    pub entry: GraphId,
    /// The module after this artifact's pipeline ran (for `show`/printing).
    pub module: Module,
    pub metrics: Metrics,
    /// Argument signature this artifact was specialized to (None = generic).
    pub signature: Option<Vec<AType>>,
    /// Inferred return type, when specialized.
    pub ret_type: Option<AType>,
}

impl Executable {
    /// Execute on argument values. `&self` and thread-safe: all per-call
    /// state lives in a per-invocation context inside the VM.
    pub fn call(&self, args: Vec<Value>) -> Result<Value> {
        self.vm.call_graph(self.entry, args)
    }

    /// [`Executable::call`] under a resource budget: instruction fuel, frame
    /// depth, tensor-bytes ceiling, and/or a deadline-carrying cancel token
    /// (see [`crate::vm::ExecBudget`]). Exceeding any limit unwinds into a
    /// structured [`crate::vm::Trap`] error — never a panic or an OOM — and
    /// bumps this artifact's cumulative [`Executable::trap_stats`].
    pub fn call_with_budget(
        &self,
        args: Vec<Value>,
        budget: &crate::vm::ExecBudget,
    ) -> Result<Value> {
        self.vm.call_graph_with(self.entry, args, budget)
    }

    /// Number of parameters the entry point takes.
    pub fn arity(&self) -> usize {
        self.module.graph(self.entry).params.len()
    }

    /// The argument-type signature this artifact was specialized to
    /// (`None` = compiled generically).
    pub fn signature(&self) -> Option<&[AType]> {
        self.signature.as_deref()
    }

    /// Inferred return type, when specialized.
    pub fn ret_type(&self) -> Option<&AType> {
        self.ret_type.as_ref()
    }

    /// Validate a prospective call against this artifact *without running
    /// it*: arity, data-kind (no closures/environments through a serving
    /// boundary), and — when the artifact is specialized — per-argument
    /// conformance to the stored signature ([`AType::accepts`], which
    /// tolerates unknown dims). This is the `Engine::check_call`-style
    /// admission check the serving layer runs before a request may enqueue,
    /// so a bad request fails at the front door instead of mid-batch.
    pub fn check_args(&self, args: &[Value]) -> Result<()> {
        let arity = self.arity();
        if args.len() != arity {
            return Err(anyhow!("expected {arity} arguments, got {}", args.len()));
        }
        for (i, arg) in args.iter().enumerate() {
            if matches!(arg, Value::Closure(_) | Value::Partial(_) | Value::Env(_) | Value::Fused(_))
            {
                return Err(anyhow!("argument {i} is a {} — not serveable data", arg.type_name()));
            }
            if let Some(expected) = self.signature.as_deref().and_then(|s| s.get(i)) {
                let actual = AType::of_value(arg);
                if !expected.accepts(&actual) {
                    return Err(anyhow!(
                        "argument {i} has type {actual}, but the artifact is specialized to \
                         {expected}"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Cumulative shape-specialization telemetry for this artifact's plan
    /// cache: kernel plans compiled, plan-cache hits, and shape misses since
    /// the artifact was built (see `vm::plan`).
    pub fn plan_stats(&self) -> crate::vm::PlanStats {
        self.vm.plan_stats()
    }

    /// Cumulative budget-trap counters for this artifact: how many calls
    /// ran out of fuel, recursion depth, tensor bytes, or deadline since the
    /// artifact was built. Never reset — the `PlanStats` idiom.
    pub fn trap_stats(&self) -> crate::vm::TrapStats {
        self.vm.trap_stats()
    }

    /// Enable or disable the shape-specializing plan tier at runtime
    /// (already-compiled plans are kept but not consulted while disabled).
    /// The `MYIA_SPECIALIZE=0` environment variable sets the initial state.
    pub fn set_specialization(&self, on: bool) {
        self.vm.set_specialization(on);
    }
}

impl Engine {
    /// Parse and lower a source module. When `MYIA_CACHE_DIR` names a
    /// usable directory, the persistent disk tier is enabled automatically
    /// (an unusable directory degrades silently to memory-only — ambient
    /// configuration must never turn a working compile into an error; use
    /// [`Engine::with_cache_dir`] to opt into strict failures).
    pub fn from_source(source: &str) -> Result<Engine> {
        let mut module = Module::new();
        let graphs = compile_source(&mut module, source)?;
        let engine = Engine {
            module,
            graphs,
            cache: ArtifactCache::new(),
            stats: Arc::new(CacheCounters::default()),
            queries: QueryEngine::new(),
            disk: match std::env::var("MYIA_CACHE_DIR") {
                Ok(dir) if !dir.is_empty() => DiskCache::new(dir).ok(),
                _ => None,
            },
        };
        engine.queries.begin_revision(&engine.module, &engine.graphs);
        Ok(engine)
    }

    /// Enable (or redirect) the persistent disk tier explicitly. Unlike the
    /// `MYIA_CACHE_DIR` path, an unusable directory is an error here.
    pub fn with_cache_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Result<Engine> {
        self.disk = Some(DiskCache::new(dir).map_err(|e| anyhow!("{e}"))?);
        Ok(self)
    }

    /// Replace the engine's source with an edited version, starting a new
    /// query revision. Artifacts for entry points whose transitive callee
    /// closure is untouched by the edit keep serving from the hot tier
    /// (their deep fingerprints still match); everything the edit reaches
    /// recompiles through the query DAG, re-running only red queries.
    pub fn update_source(&mut self, source: &str) -> Result<()> {
        let mut module = Module::new();
        let graphs = compile_source(&mut module, source)?;
        self.module = module;
        self.graphs = graphs;
        self.queries.begin_revision(&self.module, &self.graphs);
        Ok(())
    }

    /// Point-in-time artifact-cache hit/miss counts (memory + disk tiers).
    pub fn cache_stats(&self) -> CacheStats {
        self.stats.snapshot()
    }

    /// Point-in-time compilation-query telemetry: per-kind executed / green
    /// / memo counts (what the incremental tests assert deltas on).
    pub fn query_stats(&self) -> QueryStatsSnapshot {
        self.queries.snapshot()
    }

    /// The dependency edge set of `name`'s compilation: its transitive
    /// callee closure (sorted, includes `name`), or `None` for an unknown
    /// entry point.
    pub fn query_dependencies(&self, name: &str) -> Option<Vec<String>> {
        self.queries.dependencies(name)
    }

    /// The live cache counters, shareable with a serving layer so cache
    /// behavior lands in the same snapshot as serving metrics
    /// (`serve::MetricsSnapshot`).
    pub fn cache_counters(&self) -> Arc<CacheCounters> {
        self.stats.clone()
    }

    /// Graph id of a top-level function.
    pub fn graph(&self, name: &str) -> Result<GraphId> {
        self.graphs
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("no top-level function named `{name}`"))
    }

    /// Eagerly type/shape-check a call before running it (§4.2): infers from
    /// the argument types and errors on any definite mismatch.
    pub fn check_call(&self, name: &str, args: &[Value]) -> Result<AType> {
        let g = self.graph(name)?;
        let atypes: Vec<AType> = args.iter().map(AType::of_value).collect();
        crate::types::infer_call(&self.module, g, &atypes)
    }

    /// Begin a transform chain over the named entry point. The returned
    /// [`Function`] borrows the engine (shared — several chains can be in
    /// flight at once); finish the chain with [`Function::compile`] to get a
    /// cached `Arc<Executable>`.
    pub fn trace(&self, name: &str) -> Result<Function<'_>> {
        self.graph(name)?; // fail fast on unknown entry points
        Ok(Function {
            name: name.to_string(),
            engine: self,
            builder: Pipeline::builder(),
            passes: None,
            backend: Backend::Vm,
            signature: None,
        })
    }

    /// Compile `name` through `pipeline` (unspecialized). Cached.
    pub fn compile_pipeline(&self, name: &str, pipeline: &Pipeline) -> Result<Arc<Executable>> {
        self.compile_specialized(name, pipeline, None)
    }

    /// Compile `name` through `pipeline`, optionally specialized to an
    /// argument-type signature (the signature is type-checked eagerly,
    /// §4.2). Artifacts are cached under `(name, pipeline fingerprint,
    /// deep module fingerprint, signature)`; a hit performs no allocation
    /// and no compile ever runs under a cache lock. Two threads racing on
    /// the same key may both compile; the first insert wins and both
    /// receive the same artifact.
    ///
    /// Lookup order: hot tier (in-memory), then the disk tier (VM backend
    /// only; a disk hit counts as neither `hits` nor `misses` — no compile
    /// ran, but the answer wasn't in memory either), then the query DAG.
    /// Anything wrong with a disk artifact — missing, truncated, corrupt,
    /// wrong schema — degrades to a cold compile; corruption is counted
    /// (`disk_invalid`) and quarantined, never propagated as an error.
    pub fn compile_specialized(
        &self,
        name: &str,
        pipeline: &Pipeline,
        signature: Option<&[AType]>,
    ) -> Result<Arc<Executable>> {
        let (module_fp, _deps) = self
            .queries
            .entry_fingerprint(name)
            .ok_or_else(|| anyhow!("no top-level function named `{name}`"))?;
        let fp = pipeline.fingerprint();
        // The fingerprint is the fast filter; comparing the canonical spec
        // (already stored in the artifact's metrics) makes a 64-bit hash
        // collision impossible to serve.
        let matches = |e: &CacheEntry| {
            e.fingerprint == fp
                && e.module_fp == module_fp
                && e.compiled.metrics.pipeline == pipeline.spec()
                && e.signature.as_deref() == signature
        };
        let shard = self.cache.shard(name);
        {
            let guard = shard.lock().expect("artifact cache poisoned");
            if let Some(entries) = guard.get(name) {
                if let Some(hit) = entries.iter().find(|&e| matches(e)) {
                    self.stats.hits.inc();
                    return Ok(hit.compiled.clone());
                }
            }
        }
        if let Some(compiled) = self.try_disk_load(name, pipeline, signature, module_fp) {
            self.stats.disk_hits.inc();
            return Ok(self.insert_hot(shard, name, fp, module_fp, signature, compiled, &matches));
        }
        // A miss pays the full compile (even a racing loser did the work —
        // the counter measures compiles performed, not entries inserted).
        self.stats.misses.inc();
        let compiled = self.compile_via_queries(name, pipeline, signature, module_fp)?;
        if let Some(disk) = self.disk_for(pipeline) {
            let key = Self::disk_key(name, pipeline, signature, module_fp);
            if disk.store(&key, &Self::to_stored(&compiled)).is_ok() {
                self.stats.disk_writes.inc();
            }
            self.stats.disk_retries.add(disk.take_retries());
        }
        Ok(self.insert_hot(shard, name, fp, module_fp, signature, compiled, &matches))
    }

    /// Insert into the hot tier unless a racing thread beat us to the key —
    /// then serve *its* artifact so every caller shares one allocation (and
    /// one cache entry).
    #[allow(clippy::too_many_arguments)]
    fn insert_hot(
        &self,
        shard: &Mutex<HashMap<String, Vec<CacheEntry>>>,
        name: &str,
        fp: u64,
        module_fp: u64,
        signature: Option<&[AType]>,
        compiled: Arc<Executable>,
        matches: &dyn Fn(&CacheEntry) -> bool,
    ) -> Arc<Executable> {
        let mut guard = shard.lock().expect("artifact cache poisoned");
        let entries = guard.entry(name.to_string()).or_default();
        if let Some(hit) = entries.iter().find(|e| matches(e)) {
            return hit.compiled.clone();
        }
        entries.push(CacheEntry {
            fingerprint: fp,
            module_fp,
            signature: signature.map(|s| s.to_vec()),
            compiled: compiled.clone(),
        });
        compiled
    }

    /// The disk tier, when it applies to this pipeline: only VM artifacts
    /// persist (an XLA executable embeds process-local PJRT handles).
    fn disk_for(&self, pipeline: &Pipeline) -> Option<&DiskCache> {
        match pipeline.backend() {
            Backend::Vm => self.disk.as_ref(),
            _ => None,
        }
    }

    /// Canonical signature token for query labels and disk keys.
    fn sig_token(signature: Option<&[AType]>) -> String {
        match signature {
            None => "generic".to_string(),
            Some(sig) => {
                sig.iter().map(ToString::to_string).collect::<Vec<_>>().join(";")
            }
        }
    }

    fn disk_key(
        name: &str,
        pipeline: &Pipeline,
        signature: Option<&[AType]>,
        module_fp: u64,
    ) -> ArtifactKey {
        ArtifactKey {
            entry: name.to_string(),
            pipeline_spec: pipeline.spec().to_string(),
            signature: Self::sig_token(signature),
            module_fp,
        }
    }

    /// Probe the disk tier and rebuild an [`Executable`] from a stored
    /// artifact. Returns `None` on every failure mode (counting misses and
    /// invalid artifacts) — callers always have the cold path to fall back
    /// on.
    fn try_disk_load(
        &self,
        name: &str,
        pipeline: &Pipeline,
        signature: Option<&[AType]>,
        module_fp: u64,
    ) -> Option<Arc<Executable>> {
        let disk = self.disk_for(pipeline)?;
        let key = Self::disk_key(name, pipeline, signature, module_fp);
        let loaded = disk.load(&key);
        self.stats.disk_retries.add(disk.take_retries());
        let stored = match loaded {
            Ok(Some(stored)) => stored,
            Ok(None) => {
                self.stats.disk_misses.inc();
                return None;
            }
            Err(_) => {
                self.stats.disk_invalid.inc();
                return None;
            }
        };
        match Self::from_stored(stored, pipeline, signature) {
            Ok(exec) => Some(Arc::new(exec)),
            Err(_) => {
                self.stats.disk_invalid.inc();
                None
            }
        }
    }

    /// Snapshot an executable for the disk tier. The VM program itself is
    /// not serialized — codegen is deterministic and cheap relative to the
    /// transform pipeline, so a load re-runs it on the stored IR and gets a
    /// bit-identical program.
    fn to_stored(exec: &Executable) -> StoredArtifact {
        let m = &exec.metrics;
        StoredArtifact {
            module: exec.module.clone(),
            entry: exec.entry,
            signature: exec.signature.clone(),
            ret_type: exec.ret_type.clone(),
            meta: StoredMeta {
                macros_expanded: m.macros_expanded as u64,
                grad_transforms: m.grad_transforms as u64,
                nodes_after_lowering: m.nodes_after_lowering as u64,
                nodes_after_expand: m.nodes_after_expand as u64,
                nodes_after_optimize: m.nodes_after_optimize as u64,
                graphs_after_optimize: m.graphs_after_optimize as u64,
                opt_iterations: m.opt_iterations as u64,
            },
        }
    }

    /// Rebuild an executable from a disk artifact: re-run codegen on the
    /// stored post-transform IR. Transform metrics come from the stored
    /// meta; the per-stage breakdown is gone (the stages didn't run), and
    /// `codegen_us` reports the reload cost.
    fn from_stored(
        stored: StoredArtifact,
        pipeline: &Pipeline,
        signature: Option<&[AType]>,
    ) -> Result<Executable> {
        if stored.signature.as_deref() != signature {
            return Err(anyhow!("stored artifact signature mismatch"));
        }
        let t0 = Instant::now();
        let program = compile_program(&stored.module, stored.entry).map_err(|e| anyhow!("{e}"))?;
        let vm = Vm::new(program);
        let meta = stored.meta;
        let metrics = Metrics {
            pipeline: pipeline.spec().to_string(),
            codegen_us: t0.elapsed().as_micros(),
            nodes_after_lowering: meta.nodes_after_lowering as usize,
            nodes_after_expand: meta.nodes_after_expand as usize,
            nodes_after_optimize: meta.nodes_after_optimize as usize,
            graphs_after_optimize: meta.graphs_after_optimize as usize,
            macros_expanded: meta.macros_expanded as usize,
            grad_transforms: meta.grad_transforms as usize,
            opt_iterations: meta.opt_iterations as usize,
            ..Default::default()
        };
        Ok(Executable {
            vm,
            entry: stored.entry,
            module: stored.module,
            metrics,
            signature: stored.signature,
            ret_type: stored.ret_type,
        })
    }

    /// The cold path, phrased as the query DAG: ad_expand → one query per
    /// pipeline stage → typecheck (when specialized) → codegen. Each query's
    /// input fingerprint chains through the *content* fingerprint of the
    /// previous stage's output IR, so after an `update_source` only the
    /// queries an edit actually reaches re-run (the rest revalidate green);
    /// a reused stage reports its original metrics.
    fn compile_via_queries(
        &self,
        name: &str,
        pipeline: &Pipeline,
        signature: Option<&[AType]>,
        module_fp: u64,
    ) -> Result<Arc<Executable>> {
        let source_entry = self.graph(name)?;
        let backend = pipeline.backend();
        let sig_tok = Self::sig_token(signature);

        // Source-level macros (`grad(f)` written in user code) are expanded
        // unconditionally: the VM cannot execute a Macro constant, so this
        // is a semantic requirement rather than a pipeline choice — it is
        // deliberately not part of the pipeline fingerprint. The query works
        // on a private clone: the engine module stays pristine, so e.g. an
        // unoptimized pipeline compiled after an optimized one of the same
        // entry really is unoptimized.
        let expanded = self.queries.get_ir(
            QueryKind::AdExpand,
            &format!("expand:{name}"),
            module_fp,
            || {
                let mut m = self.module.clone();
                let nodes_before = m.reachable_node_count(source_entry);
                let mut stage =
                    StageMetrics { name: "expand_macros".to_string(), ..Default::default() };
                let t0 = Instant::now();
                let n = expand_macros(&mut m, source_entry)?;
                stage.us = t0.elapsed().as_micros();
                stage.nodes_after = m.reachable_node_count(source_entry);
                stage.detail.push(("macros_expanded".to_string(), n));
                let output_fp = content_fingerprint(&m, source_entry);
                Ok(Arc::new(IrSnapshot {
                    module: m,
                    entry: source_entry,
                    output_fp,
                    stage,
                    nodes_before,
                }))
            },
        )?;

        let mut cur = expanded.clone();
        let mut stage_snaps: Vec<Arc<IrSnapshot>> = Vec::with_capacity(pipeline.stages().len());
        for (t, prefix) in pipeline.stages().iter().zip(pipeline.stage_key_prefixes()) {
            // The label carries the cumulative upstream stage keys: two
            // pipelines sharing a prefix share these queries and their
            // memoized IR.
            let label = format!("{name}|{prefix}|{}", backend.key());
            let input_fp = mix_fp(cur.output_fp, &[&t.key(), backend.key()]);
            let kind = if t.name() == "optimize" {
                QueryKind::Optimize
            } else {
                QueryKind::AdExpand
            };
            let prev = cur.clone();
            let next = self.queries.get_ir(kind, &label, input_fp, || {
                let mut m = prev.module.clone();
                let nodes_before = m.reachable_node_count(prev.entry);
                let mut stage =
                    StageMetrics { name: t.name().to_string(), ..Default::default() };
                let t0 = Instant::now();
                let entry = t.apply_for_backend(&mut m, prev.entry, &mut stage, backend)?;
                stage.us = t0.elapsed().as_micros();
                stage.nodes_after = m.reachable_node_count(entry);
                let output_fp = content_fingerprint(&m, entry);
                Ok(Arc::new(IrSnapshot { module: m, entry, output_fp, stage, nodes_before }))
            })?;
            stage_snaps.push(next.clone());
            cur = next;
        }

        // Eager per-signature specialization check (§4.2), keyed by the
        // final IR's content fingerprint and the signature.
        let ret_type = match signature {
            Some(sig) => {
                let label = format!("{name}|{}|{sig_tok}", pipeline.spec());
                let input_fp = mix_fp(cur.output_fp, &[&sig_tok]);
                let final_snap = &cur;
                Some(self.queries.get_type(&label, input_fp, || {
                    crate::types::infer_call(&final_snap.module, final_snap.entry, sig)
                })?)
            }
            None => None,
        };

        let codegen_label = format!("{name}|{}|{sig_tok}", pipeline.spec());
        let input_fp = mix_fp(cur.output_fp, &[&sig_tok, backend.key()]);
        self.queries.get_exec(&codegen_label, input_fp, || {
            let mut metrics =
                Metrics { pipeline: pipeline.spec().to_string(), ..Default::default() };
            metrics.nodes_after_lowering = expanded.nodes_before;
            for (k, v) in &expanded.stage.detail {
                if k == "macros_expanded" {
                    metrics.macros_expanded += *v;
                }
            }
            metrics.expand_us = expanded.stage.us;
            metrics.nodes_after_expand = expanded.stage.nodes_after;
            for snap in &stage_snaps {
                let sm = &snap.stage;
                for (k, v) in &sm.detail {
                    match k.as_str() {
                        "grad_order" => metrics.grad_transforms += *v,
                        "iterations" => metrics.opt_iterations += *v,
                        _ => {}
                    }
                }
                match sm.name.as_str() {
                    "grad" | "value_and_grad" => {
                        metrics.expand_us += sm.us;
                        metrics.nodes_after_expand = sm.nodes_after;
                    }
                    "optimize" => metrics.optimize_us += sm.us,
                    _ => {}
                }
                metrics.stages.push(sm.clone());
            }

            let analysis = analyze(&cur.module, cur.entry);
            metrics.nodes_after_optimize = analysis.node_count(&cur.module);
            metrics.graphs_after_optimize = analysis.graphs.len();

            let module = cur.module.clone();
            let t2 = Instant::now();
            let program = compile_program(&module, cur.entry).map_err(|e| anyhow!("{e}"))?;
            let mut vm = Vm::new(program);
            if backend == Backend::Xla {
                metrics.xla_segments = crate::backend::install_segments(&mut vm)?;
            }
            metrics.codegen_us = t2.elapsed().as_micros();

            Ok(Arc::new(Executable {
                vm,
                entry: cur.entry,
                module,
                metrics,
                signature: signature.map(|s| s.to_vec()),
                ret_type: ret_type.clone(),
            }))
        })
    }
}

/// A traced entry point: a handle that accumulates transforms and compiles
/// into a cached artifact. Obtained from [`Engine::trace`].
///
/// Transform methods consume and return the handle, so chains read like the
/// math: `e.trace("f")?.grad().grad().compile()?` is d²f/dx².
pub struct Function<'e> {
    engine: &'e Engine,
    name: String,
    builder: crate::transform::PipelineBuilder,
    passes: Option<PassSet>,
    backend: Backend,
    signature: Option<Vec<AType>>,
}

impl<'e> Function<'e> {
    /// Differentiate w.r.t. the first parameter (reverse mode). Chainable:
    /// each call raises the derivative order by one.
    pub fn grad(mut self) -> Self {
        self.builder = self.builder.grad();
        self
    }

    /// Differentiate w.r.t. parameter `wrt`.
    pub fn grad_wrt(mut self, wrt: usize) -> Self {
        self.builder = self.builder.grad_wrt(wrt);
        self
    }

    /// Rewrite to return `(value, gradient)`, sharing the forward pass.
    pub fn value_and_grad(mut self) -> Self {
        self.builder = self.builder.value_and_grad();
        self
    }

    /// Rewrite to return `(value, gradient)` w.r.t. parameter `wrt`.
    pub fn value_and_grad_wrt(mut self, wrt: usize) -> Self {
        self.builder = self.builder.value_and_grad_wrt(wrt);
        self
    }

    /// Batch every parameter along axis 0 (the `Vmap` transform). Composes
    /// with `grad` in both orders: `f.grad().vmap()` is per-example
    /// gradients; `f.vmap().grad()` differentiates the batched program.
    pub fn vmap(mut self) -> Self {
        self.builder = self.builder.vmap();
        self
    }

    /// Batch with explicit per-parameter axes; `None` entries are broadcast
    /// (shared across the batch) rather than mapped.
    pub fn vmap_axes(mut self, in_axes: Vec<Option<usize>>) -> Self {
        self.builder = self.builder.vmap_axes(in_axes);
        self
    }

    /// Append a user-defined IR transform. Lowering is not expressible
    /// here — the handle appends its own final lowering stage, so a
    /// transform with `lower_to()` set is rejected when the pipeline is
    /// built (same behavior as [`crate::transform::PipelineBuilder`]);
    /// select the backend with [`Function::jit`] instead.
    pub fn transform(mut self, t: impl Transform + 'static) -> Self {
        self.builder = self.builder.transform(t);
        self
    }

    /// Select the optimization pass set (default: [`PassSet::Standard`]).
    pub fn optimize(mut self, passes: PassSet) -> Self {
        self.passes = Some(passes);
        self
    }

    /// Lower to `backend` (default: the VM). `jit(Backend::Xla)` compiles
    /// straight-line tensor segments with XLA.
    pub fn jit(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Specialize to an argument-type signature: the signature joins the
    /// cache key and is eagerly type/shape-checked at compile time (§4.2).
    pub fn specialize(mut self, signature: Vec<AType>) -> Self {
        self.signature = Some(signature);
        self
    }

    /// The pipeline this handle currently describes: accumulated transforms,
    /// then optimization, then lowering.
    pub fn pipeline(&self) -> Result<Pipeline> {
        let passes = self.passes.clone().unwrap_or(PassSet::Standard);
        self.builder.clone().optimize(passes).lower(self.backend).build()
    }

    /// Run the pipeline and return the (cached) compiled artifact — an
    /// `Arc<Executable>` that is `Send + Sync` and callable from any thread.
    pub fn compile(self) -> Result<Arc<Executable>> {
        let pipeline = self.pipeline()?;
        self.engine.compile_specialized(&self.name, &pipeline, self.signature.as_deref())
    }
}

/// One-shot convenience: compile `entry` from `source` and run it.
pub fn run_source(source: &str, entry: &str, args: Vec<Value>) -> Result<Value> {
    let e = Engine::from_source(source)?;
    let f = e.compile_pipeline(entry, &Pipeline::standard(Backend::Vm))?;
    f.call(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_grad_pipeline() {
        let src = "\
def f(x):
    return x ** 3.0

def main(x):
    return grad(f)(x)
";
        let e = Engine::from_source(src).unwrap();
        let f = e.trace("main").unwrap().compile().unwrap();
        let out = f.call(vec![Value::F64(2.0)]).unwrap();
        assert!((out.as_f64().unwrap() - 12.0).abs() < 1e-12);
        assert_eq!(f.metrics.macros_expanded, 1);
        assert_eq!(f.metrics.pipeline, "opt=standard,vm");
        // Optimization must shrink the expanded program substantially.
        assert!(
            f.metrics.nodes_after_optimize < f.metrics.nodes_after_expand / 2,
            "{} -> {}",
            f.metrics.nodes_after_expand,
            f.metrics.nodes_after_optimize
        );
    }

    #[test]
    fn cache_hits_and_misses() {
        let e = Engine::from_source("def f(x):\n    return x + 1.0\n").unwrap();
        let a = e.trace("f").unwrap().compile().unwrap();
        let b = e.trace("f").unwrap().compile().unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        // A different pass set is a different pipeline.
        let c = e.trace("f").unwrap().optimize(PassSet::None).compile().unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        // Equivalent pipelines built explicitly share the same entry.
        let d = e
            .compile_pipeline("f", &Pipeline::standard(Backend::Vm))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &d));
        // The unified telemetry saw exactly these four lookups.
        let stats = e.cache_stats();
        assert_eq!((stats.hits, stats.misses), (2, 2), "{stats:?}");
    }

    #[test]
    fn check_args_validates_against_stored_signature() {
        let e = Engine::from_source("def f(w, x):\n    return sum(w * x)\n").unwrap();
        let sig = vec![
            AType::Tensor { dtype: crate::tensor::DType::F64, shape: vec![Some(3)] },
            AType::Tensor { dtype: crate::tensor::DType::F64, shape: vec![Some(3)] },
        ];
        let f = e.trace("f").unwrap().specialize(sig).compile().unwrap();
        assert_eq!(f.arity(), 2);
        assert_eq!(f.signature().map(<[AType]>::len), Some(2));
        let good = crate::tensor::Tensor::from_f64(&[1.0, 2.0, 3.0]);
        let bad = crate::tensor::Tensor::from_f64(&[1.0, 2.0]);
        f.check_args(&[Value::Tensor(good.clone()), Value::Tensor(good.clone())]).unwrap();
        // Wrong shape, wrong kind, wrong arity — each caught before a call.
        assert!(f
            .check_args(&[Value::Tensor(good.clone()), Value::Tensor(bad)])
            .is_err());
        assert!(f.check_args(&[Value::Tensor(good.clone()), Value::F64(1.0)]).is_err());
        assert!(f.check_args(&[Value::Tensor(good)]).is_err());
        // Generic artifacts still enforce arity and data-kind.
        let g = e.trace("f").unwrap().compile().unwrap();
        assert!(g.signature().is_none());
        g.check_args(&[Value::F64(1.0), Value::F64(2.0)]).unwrap();
        assert!(g.check_args(&[Value::F64(1.0)]).is_err());
    }

    #[test]
    fn concurrent_compiles_share_one_artifact() {
        // Many threads race the same (entry, pipeline) key on one shared
        // engine; everyone must end up with the same Arc'd artifact and the
        // correct derivative.
        let e = Engine::from_source("def f(x):\n    return x ** 3.0\n").unwrap();
        let results: Vec<Arc<Executable>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| s.spawn(|| e.trace("f").unwrap().grad().compile().unwrap()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for f in &results {
            let got = f.call(vec![Value::F64(2.0)]).unwrap().as_f64().unwrap();
            assert!((got - 12.0).abs() < 1e-12);
        }
        // All callers share one cache entry (first insert won the race).
        let first = e.trace("f").unwrap().grad().compile().unwrap();
        assert!(results.iter().all(|f| Arc::ptr_eq(f, &first)));
    }

    #[test]
    fn unoptimized_still_correct() {
        let src = "\
def f(x):
    return sin(x) * x

def main(x):
    return grad(f)(x)
";
        let e = Engine::from_source(src).unwrap();
        let f = e.trace("main").unwrap().optimize(PassSet::None).compile().unwrap();
        let out = f.call(vec![Value::F64(0.9)]).unwrap();
        let want = 0.9f64.cos() * 0.9 + 0.9f64.sin();
        assert!((out.as_f64().unwrap() - want).abs() < 1e-12);
    }

    #[test]
    fn missing_entry_reported() {
        let e = Engine::from_source("def f(x):\n    return x\n").unwrap();
        assert!(e.trace("nope").is_err());
    }

    #[test]
    fn standard_artifact_has_zero_unreachable_graphs() {
        // The dead-graph GC finalizer must leave the artifact's module
        // containing exactly the graphs its entry reaches — nothing else.
        let src = "\
def f(x):
    return x ** 3.0

def unrelated(y):
    return y + 1.0

def main(x):
    return grad(f)(x)
";
        let e = Engine::from_source(src).unwrap();
        let f = e.trace("main").unwrap().compile().unwrap();
        let live = analyze(&f.module, f.entry).graphs.len();
        assert_eq!(
            f.module.num_graphs(),
            live,
            "artifact carries {} graphs but only {live} are reachable",
            f.module.num_graphs()
        );
    }

    #[test]
    fn update_source_retires_only_affected_entries() {
        let v1 = "def f(x):\n    return x + 1.0\n\ndef g(x):\n    return x * 2.0\n";
        let v2 = "def f(x):\n    return x + 1.0\n\ndef g(x):\n    return x * 3.0\n";
        let mut e = Engine::from_source(v1).unwrap();
        let f1 = e.trace("f").unwrap().compile().unwrap();
        let g1 = e.trace("g").unwrap().compile().unwrap();
        e.update_source(v2).unwrap();
        // `f` is untouched by the edit: its deep fingerprint still matches,
        // so the hot tier keeps serving the original artifact.
        let f2 = e.trace("f").unwrap().compile().unwrap();
        assert!(Arc::ptr_eq(&f1, &f2), "untouched entry must keep its artifact");
        // `g` changed: its entry stops matching and a fresh compile runs.
        let g2 = e.trace("g").unwrap().compile().unwrap();
        assert!(!Arc::ptr_eq(&g1, &g2), "edited entry must recompile");
        let got = g2.call(vec![Value::F64(2.0)]).unwrap().as_f64().unwrap();
        assert!((got - 6.0).abs() < 1e-12);
        let stats = e.cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 3), "{stats:?}");
        assert_eq!(e.query_stats().parse.executed, 2);
        assert_eq!(e.query_dependencies("f"), Some(vec!["f".to_string()]));
    }

    #[test]
    fn disk_tier_round_trips_across_engines() {
        let dir = std::env::temp_dir()
            .join(format!("myia-engine-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let src = "def f(x):\n    return sin(x) * x\n";
        let cold = {
            let e = Engine::from_source(src).unwrap().with_cache_dir(&dir).unwrap();
            let f = e.trace("f").unwrap().grad().compile().unwrap();
            let stats = e.cache_stats();
            assert_eq!((stats.disk_hits, stats.disk_misses), (0, 1), "{stats:?}");
            assert!(stats.disk_writes >= 1, "{stats:?}");
            f.call(vec![Value::F64(0.7)]).unwrap().as_f64().unwrap()
        };
        // A second engine (fresh process stand-in) starts warm from disk:
        // no compile runs and execution is bit-identical.
        let e = Engine::from_source(src).unwrap().with_cache_dir(&dir).unwrap();
        let f = e.trace("f").unwrap().grad().compile().unwrap();
        let stats = e.cache_stats();
        assert_eq!((stats.disk_hits, stats.misses), (1, 0), "{stats:?}");
        let warm = f.call(vec![Value::F64(0.7)]).unwrap().as_f64().unwrap();
        assert_eq!(cold.to_bits(), warm.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn function_grad_matches_macro_grad() {
        // Programmatic .grad() and source-level grad(f) agree.
        let src = "\
def f(x):
    return x ** 3.0

def main(x):
    return grad(f)(x)
";
        let e = Engine::from_source(src).unwrap();
        let via_macro = e.trace("main").unwrap().compile().unwrap();
        let via_transform = e.trace("f").unwrap().grad().compile().unwrap();
        for x in [0.5, -1.0, 2.0] {
            let a = via_macro.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap();
            let b = via_transform.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap();
            assert!((a - b).abs() < 1e-12, "x={x}: {a} vs {b}");
        }
    }
}

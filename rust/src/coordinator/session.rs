//! Compilation sessions and compiled entry points.

use crate::ad::expand_macros;
use crate::ir::{analyze, GraphId, Module};
use crate::opt::Optimizer;
use crate::parser::compile_source;
use crate::vm::{compile_program, Value, Vm};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

/// Pipeline options.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Options {
    /// Run the optimizer (§4.3). Off = the "interpreted, unoptimized" arm.
    pub optimize: bool,
    /// Extract straight-line tensor segments and compile them with XLA
    /// (requires the PJRT runtime; the paper's TVM role).
    pub xla_backend: bool,
    /// Reserved: run extra verification passes.
    pub infer: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options { optimize: true, xla_backend: false, infer: false }
    }
}

/// Compile-time metrics (E1/E6/E7 read these).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub parse_lower_us: u128,
    pub expand_us: u128,
    pub optimize_us: u128,
    pub codegen_us: u128,
    pub nodes_after_lowering: usize,
    pub nodes_after_expand: usize,
    pub nodes_after_optimize: usize,
    pub graphs_after_optimize: usize,
    pub macros_expanded: usize,
    pub opt_iterations: usize,
    pub xla_segments: usize,
}

/// A compilation session over one source module.
pub struct Session {
    pub module: Module,
    pub graphs: HashMap<String, GraphId>,
    cache: HashMap<(String, Options), Rc<CompiledFn>>,
}

/// A compiled, executable entry point.
pub struct CompiledFn {
    pub vm: Vm,
    pub entry: GraphId,
    pub metrics: Metrics,
}

impl CompiledFn {
    pub fn call(&self, args: Vec<Value>) -> Result<Value> {
        self.vm.call_graph(self.entry, args)
    }
}

impl Session {
    /// Parse and lower a source module.
    pub fn from_source(source: &str) -> Result<Session> {
        let mut module = Module::new();
        let graphs = compile_source(&mut module, source)?;
        Ok(Session { module, graphs, cache: HashMap::new() })
    }

    /// Graph id of a top-level function.
    pub fn graph(&self, name: &str) -> Result<GraphId> {
        self.graphs
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("no top-level function named `{name}`"))
    }

    /// Eagerly type/shape-check a call before running it (§4.2): infers from
    /// the argument types and errors on any definite mismatch.
    pub fn check_call(&self, name: &str, args: &[Value]) -> Result<crate::types::AType> {
        let g = self.graph(name)?;
        let atypes: Vec<crate::types::AType> =
            args.iter().map(crate::types::AType::of_value).collect();
        crate::types::infer_call(&self.module, g, &atypes)
    }

    /// Compile an entry point (cached on (name, options)).
    pub fn compile(&mut self, name: &str, options: Options) -> Result<Rc<CompiledFn>> {
        let key = (name.to_string(), options.clone());
        if let Some(f) = self.cache.get(&key) {
            return Ok(f.clone());
        }
        let f = Rc::new(self.compile_uncached(name, &options)?);
        self.cache.insert(key, f.clone());
        Ok(f)
    }

    fn compile_uncached(&mut self, name: &str, options: &Options) -> Result<CompiledFn> {
        let entry = self.graph(name)?;
        let m = &mut self.module;
        let mut metrics = Metrics::default();
        metrics.nodes_after_lowering = m.reachable_node_count(entry);

        let t0 = Instant::now();
        metrics.macros_expanded = expand_macros(m, entry)?;
        metrics.expand_us = t0.elapsed().as_micros();
        metrics.nodes_after_expand = m.reachable_node_count(entry);

        let t1 = Instant::now();
        if options.optimize {
            let stats = Optimizer::standard().run(m, entry)?;
            metrics.opt_iterations = stats.iterations;
        }
        metrics.optimize_us = t1.elapsed().as_micros();
        let analysis = analyze(m, entry);
        metrics.nodes_after_optimize = analysis.node_count(m);
        metrics.graphs_after_optimize = analysis.graphs.len();

        let t2 = Instant::now();
        let program = compile_program(m, entry).map_err(|e| anyhow!("{e}"))?;
        let mut vm = Vm::new(program);
        if options.xla_backend {
            metrics.xla_segments = crate::backend::install_segments(&mut vm)?;
        }
        metrics.codegen_us = t2.elapsed().as_micros();

        Ok(CompiledFn { vm, entry, metrics })
    }
}

/// One-shot convenience: compile `entry` from `source` and run it.
pub fn run_source(source: &str, entry: &str, args: Vec<Value>) -> Result<Value> {
    let mut s = Session::from_source(source)?;
    let f = s.compile(entry, Options::default())?;
    f.call(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_grad_pipeline() {
        let src = "\
def f(x):
    return x ** 3.0

def main(x):
    return grad(f)(x)
";
        let mut s = Session::from_source(src).unwrap();
        let f = s.compile("main", Options::default()).unwrap();
        let out = f.call(vec![Value::F64(2.0)]).unwrap();
        assert!((out.as_f64().unwrap() - 12.0).abs() < 1e-12);
        assert_eq!(f.metrics.macros_expanded, 1);
        // Optimization must shrink the expanded program substantially.
        assert!(
            f.metrics.nodes_after_optimize < f.metrics.nodes_after_expand / 2,
            "{} -> {}",
            f.metrics.nodes_after_expand,
            f.metrics.nodes_after_optimize
        );
    }

    #[test]
    fn cache_hits() {
        let mut s = Session::from_source("def f(x):\n    return x + 1.0\n").unwrap();
        let a = s.compile("f", Options::default()).unwrap();
        let b = s.compile("f", Options::default()).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        let c = s.compile("f", Options { optimize: false, ..Default::default() }).unwrap();
        assert!(!Rc::ptr_eq(&a, &c));
    }

    #[test]
    fn unoptimized_still_correct() {
        let src = "\
def f(x):
    return sin(x) * x

def main(x):
    return grad(f)(x)
";
        let mut s = Session::from_source(src).unwrap();
        let f = s.compile("main", Options { optimize: false, ..Default::default() }).unwrap();
        let out = f.call(vec![Value::F64(0.9)]).unwrap();
        let want = 0.9f64.cos() * 0.9 + 0.9f64.sin();
        assert!((out.as_f64().unwrap() - want).abs() < 1e-12);
    }

    #[test]
    fn missing_entry_reported() {
        let mut s = Session::from_source("def f(x):\n    return x\n").unwrap();
        assert!(s.compile("nope", Options::default()).is_err());
    }
}

//! The MLP workload shared by `examples/train_mlp` and the E3 benches.
//!
//! The model is written in the Myia source language (the same architecture
//! as `python/compile/model.py`): parameters travel as one tuple so that
//! `grad` — which differentiates with respect to the first argument —
//! returns the gradient of the whole parameter pytree, exactly like
//! `jax.grad` over a params tuple.

use crate::backend::Backend;
use crate::coordinator::{Engine, Executable};
use crate::tensor::{ops, DType, Rng, Tensor};
use crate::vm::Value;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Model dimensions for the MLP workload (formerly part of the deleted
/// JAX-artifact loading path; now owned by the workload itself).
#[derive(Debug, Clone, PartialEq)]
pub struct MlpMeta {
    pub batch: usize,
    pub in_dim: usize,
    pub h1: usize,
    pub h2: usize,
    pub out_dim: usize,
    pub lr: f64,
}

impl MlpMeta {
    /// Parameter shapes in call order (w1, b1, w2, b2, w3, b3).
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![
            vec![self.in_dim, self.h1],
            vec![self.h1],
            vec![self.h1, self.h2],
            vec![self.h2],
            vec![self.h2, self.out_dim],
            vec![self.out_dim],
        ]
    }

    /// Deterministic f32 parameter init matching [`MlpMeta::param_shapes`].
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        self.param_shapes()
            .into_iter()
            .map(|shape| {
                let fan_in = shape[0].max(1) as f64;
                let scale = if shape.len() == 2 { 1.0 / fan_in.sqrt() } else { 0.0 };
                rng.normal_tensor(&shape, scale).cast(DType::F32)
            })
            .collect()
    }
}

/// The MLP in the Myia source language.
pub const MLP_SOURCE: &str = "\
def mlp_loss(params, x, y):
    w1 = params[0]
    b1 = params[1]
    w2 = params[2]
    b2 = params[3]
    w3 = params[4]
    b3 = params[5]
    h1 = tanh(matmul(x, w1) + b1)
    h2 = tanh(matmul(h1, w2) + b2)
    logits = matmul(h2, w3) + b3
    p = softmax(logits)
    picked = sum_last_keep(p * y)
    losses = neg(log(picked))
    return item(mean(losses))

def mlp_grad(params, x, y):
    return grad(mlp_loss)(params, x, y)
";

/// Synthetic linearly-separable-ish classification data: labels come from a
/// random ground-truth projection, so the MLP can actually learn.
pub fn synth_batch(meta: &MlpMeta, rng: &mut Rng, w_true: &Tensor) -> (Tensor, Tensor) {
    let x = rng.normal_tensor(&[meta.batch, meta.in_dim], 1.0);
    let scores = crate::tensor::matmul(&x, w_true).expect("shapes");
    let labels = ops::argmax_last(&scores).expect("argmax");
    let y = ops::one_hot(&labels, meta.out_dim).expect("one_hot");
    (x, y)
}

/// Ground-truth projection for the synthetic task.
pub fn synth_teacher(meta: &MlpMeta, rng: &mut Rng) -> Tensor {
    rng.normal_tensor(&[meta.in_dim, meta.out_dim], 1.0)
}

/// Parameters as a Myia tuple value.
pub fn params_value(params: &[Tensor]) -> Value {
    Value::tuple(params.iter().cloned().map(Value::Tensor).collect())
}

/// SGD step on the Rust side: p ← p − lr·g.
pub fn sgd_update(params: &[Tensor], grads: &Value, lr: f64) -> Result<Vec<Tensor>> {
    let gs = match grads {
        Value::Tuple(items) => items,
        other => return Err(anyhow!("expected gradient tuple, got {other}")),
    };
    params
        .iter()
        .zip(gs.iter())
        .map(|(p, g)| {
            let g = match g {
                Value::Tensor(t) => t.clone(),
                Value::ZeroT => Tensor::zeros(p.dtype(), p.shape()),
                other => return Err(anyhow!("non-tensor gradient {other}")),
            };
            let lr_t = Tensor::scalar_f64(lr);
            let step = ops::mul(&g, &lr_t).map_err(|e| anyhow!("{e}"))?;
            ops::sub(p, &step).map_err(|e| anyhow!("{e}")).map(|t| t.cast(p.dtype()))
        })
        .collect()
}

/// Compile the Myia MLP loss+grad entry points. The gradient is derived
/// from the loss with the transform API — `value_and_grad` is a pipeline
/// stage, not a string in the model source.
pub fn compile_mlp(xla: bool) -> Result<(Engine, Arc<Executable>, Arc<Executable>)> {
    let e = Engine::from_source(MLP_SOURCE)?;
    let backend = if xla { Backend::Xla } else { Backend::Vm };
    let loss = e.trace("mlp_loss")?.jit(backend).compile()?;
    let grad = e.trace("mlp_loss")?.value_and_grad().jit(backend).compile()?;
    Ok((e, loss, grad))
}

/// Compile ∂loss/∂params *per example*: the `Grad` transform builds the
/// adjoint of the loss w.r.t. the parameter pytree, then `Vmap` maps the
/// adjoint program over the example axes of `(x, y)` with the parameters
/// shared — JAX's `vmap(grad(loss), in_axes=(None, 0, 0))`, assembled from
/// pipeline stages. The compiled function takes `(params, xs, ys)` with
/// `xs: [N, 1, in]`, `ys: [N, 1, out]` (see [`per_example_rows`]) and
/// returns a params-shaped tuple whose leaves carry a leading `N` axis.
pub fn compile_per_sample_grads(
    e: &Engine,
    xla: bool,
) -> Result<Arc<Executable>> {
    if xla {
        // Fail fast with context rather than deep in segment lowering: the
        // batching prims (batch_matmul, sum_tail, ...) have no XLA rules.
        return Err(anyhow!(
            "per-sample gradients are VM-only for now: the Vmap batching \
             primitives have no XLA lowering"
        ));
    }
    e.trace("mlp_loss")?
        .grad()
        .vmap_axes(vec![None, Some(0), Some(0)])
        .jit(Backend::Vm)
        .compile()
}

/// Reshape a `[N, d]` batch into `[N, 1, d]`: each example becomes a
/// batch-of-one row matrix, the layout the row-matrix MLP maps over.
pub fn per_example_rows(x: &Tensor) -> Result<Tensor> {
    if x.rank() != 2 {
        return Err(anyhow!("per_example_rows expects [N, d], got {:?}", x.shape()));
    }
    x.reshape(&[x.shape()[0], 1, x.shape()[1]]).map_err(|e| anyhow!("{e}"))
}

/// One Myia training step; returns the loss.
pub fn myia_step(
    grad_fn: &Executable,
    params: &mut Vec<Tensor>,
    x: &Tensor,
    y: &Tensor,
    lr: f64,
) -> Result<f64> {
    let out = grad_fn.call(vec![
        params_value(params),
        Value::Tensor(x.clone()),
        Value::Tensor(y.clone()),
    ])?;
    let (loss, grads) = match &out {
        Value::Tuple(items) => (items[0].clone(), items[1].clone()),
        other => return Err(anyhow!("expected (loss, grads), got {other}")),
    };
    *params = sgd_update(params, &grads, lr)?;
    loss.as_f64().ok_or_else(|| anyhow!("non-scalar loss"))
}

/// Default meta when artifacts haven't been built (keeps CPU-only flows
/// runnable); matches python/compile/model.py.
pub fn default_meta() -> MlpMeta {
    MlpMeta { batch: 32, in_dim: 64, h1: 128, h2: 64, out_dim: 10, lr: 0.05 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn myia_mlp_trains() {
        let meta = default_meta();
        let mut rng = Rng::new(17);
        let teacher = synth_teacher(&meta, &mut rng);
        let (_s, loss_fn, grad_fn) = compile_mlp(false).unwrap();
        let mut params: Vec<Tensor> =
            meta.init_params(3).into_iter().map(|t| t.cast(DType::F64)).collect();
        let (x, y) = synth_batch(&meta, &mut rng, &teacher);
        let first = loss_fn
            .call(vec![params_value(&params), Value::Tensor(x.clone()), Value::Tensor(y.clone())])
            .unwrap()
            .as_f64()
            .unwrap();
        let mut last = first;
        for _ in 0..8 {
            last = myia_step(&grad_fn, &mut params, &x, &y, meta.lr).unwrap();
        }
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn per_sample_grads_match_looped_single_example_grads() {
        let meta = MlpMeta { batch: 4, in_dim: 5, h1: 6, h2: 5, out_dim: 3, lr: 0.05 };
        let mut rng = Rng::new(11);
        let teacher = synth_teacher(&meta, &mut rng);
        let (x, y) = synth_batch(&meta, &mut rng, &teacher);
        let params: Vec<Tensor> =
            meta.init_params(2).into_iter().map(|t| t.cast(DType::F64)).collect();

        let e = Engine::from_source(MLP_SOURCE).unwrap();
        let per_sample = compile_per_sample_grads(&e, false).unwrap();
        let xs = per_example_rows(&x).unwrap();
        let ys = per_example_rows(&y).unwrap();
        let batched = per_sample
            .call(vec![params_value(&params), Value::Tensor(xs), Value::Tensor(ys)])
            .unwrap();
        let batched = match batched {
            Value::Tuple(items) => items,
            other => panic!("expected per-sample gradient tuple, got {other}"),
        };
        assert_eq!(batched.len(), params.len());

        // Oracle: the same Grad pipeline looped over single examples.
        let grad1 = e.trace("mlp_loss").unwrap().grad().compile().unwrap();
        for e in 0..meta.batch {
            let xe = ops::take_row(&x, e).unwrap().reshape(&[1, meta.in_dim]).unwrap();
            let ye = ops::take_row(&y, e).unwrap().reshape(&[1, meta.out_dim]).unwrap();
            let ge = grad1
                .call(vec![params_value(&params), Value::Tensor(xe), Value::Tensor(ye)])
                .unwrap();
            let ge = match ge {
                Value::Tuple(items) => items,
                other => panic!("{other}"),
            };
            for (slot, (bg, pg)) in batched.iter().zip(ge.iter()).enumerate() {
                let bt = bg.as_tensor().unwrap();
                let pt = pg.as_tensor().unwrap();
                // slice example e out of the stacked gradient
                let row = ops::take_row(bt, e).unwrap();
                let flat_row = row.reshape(&[row.numel()]).unwrap();
                let flat_ref =
                    pt.reshape(&[pt.numel()]).unwrap();
                assert!(
                    flat_row.allclose(&flat_ref, 1e-9),
                    "param {slot}, example {e}: per-sample grad disagrees with loop \
                     ({} vs {})",
                    flat_row.max_abs_diff(&flat_ref).unwrap(),
                    1e-9
                );
            }
        }
    }

    #[test]
    fn grads_shape_match_params() {
        let meta = default_meta();
        let mut rng = Rng::new(5);
        let teacher = synth_teacher(&meta, &mut rng);
        let (_s, _loss, grad_fn) = compile_mlp(false).unwrap();
        let params: Vec<Tensor> =
            meta.init_params(1).into_iter().map(|t| t.cast(DType::F64)).collect();
        let (x, y) = synth_batch(&meta, &mut rng, &teacher);
        let out = grad_fn
            .call(vec![params_value(&params), Value::Tensor(x), Value::Tensor(y)])
            .unwrap();
        match out {
            Value::Tuple(items) => match &items[1] {
                Value::Tuple(gs) => {
                    assert_eq!(gs.len(), 6);
                    for (g, p) in gs.iter().zip(params.iter()) {
                        assert_eq!(g.as_tensor().unwrap().shape(), p.shape());
                    }
                }
                other => panic!("{other}"),
            },
            other => panic!("{other}"),
        }
    }
}

//! The compilation pipeline driver.
//!
//! Orchestrates the full toolchain the paper describes: parse → lower →
//! macro (grad) expansion → transform pipeline (grad / optimize / lower) →
//! VM codegen (optionally with XLA segment extraction) → execution. The
//! public surface is [`Session::trace`] + [`Function`]: transforms compose
//! as first-class values, and compiled entry points are cached by
//! `(entry, pipeline fingerprint, argument-type signature)` so repeated
//! `grad` calls pay the source-transformation cost once (§2.1.2: "the AD
//! transformation is done only once per program and hence doesn't incur
//! overhead at runtime").

pub mod mlp;
mod session;

#[allow(deprecated)]
pub use session::Options;
pub use session::{run_source, CompiledFn, Function, Metrics, Session};

//! The compilation pipeline driver.
//!
//! Orchestrates the full toolchain the paper describes: parse → lower →
//! macro (grad) expansion → type/shape specialization → optimization →
//! VM codegen (optionally with XLA segment extraction) → execution. Compiled
//! entry points are cached by (source, entry, options) so repeated `grad`
//! calls pay the source-transformation cost once (§2.1.2: "the AD
//! transformation is done only once per program and hence doesn't incur
//! overhead at runtime").

pub mod mlp;
mod session;

pub use session::{CompiledFn, Metrics, Options, Session};

//! The compilation pipeline driver.
//!
//! Orchestrates the full toolchain the paper describes: parse → lower →
//! macro (grad) expansion → transform pipeline (grad / vmap / optimize /
//! lower) → VM codegen (optionally with XLA segment extraction) →
//! execution, behind an explicit compile/run split:
//!
//! * [`Engine`] (compile time) owns the parsed module, the transform
//!   machinery, and a sharded `Mutex`-protected artifact cache keyed by
//!   `(entry, pipeline fingerprint, argument-type signature)` — so repeated
//!   `grad` requests pay the source-transformation cost once (§2.1.2: "the
//!   AD transformation is done only once per program and hence doesn't
//!   incur overhead at runtime"). All compile entry points take `&self`.
//! * [`Executable`] (run time) is the immutable compiled artifact:
//!   `Send + Sync`, shared as `Arc<Executable>`, callable concurrently from
//!   any number of threads with results identical to sequential execution.
//!
//! The public surface is [`Engine::trace`] + [`Function`]: transforms
//! compose as first-class values.

pub mod engine;
pub mod mlp;

pub use engine::{run_source, Engine, Executable, Function, Metrics};

//! Integration tests for the micro-batching serving subsystem.
//!
//! The contract under test: **batching must be invisible**. Whatever batch a
//! request rides in — full, partial, singleton, or one that failed and fell
//! back — its response must be bit-identical to what the unbatched pipeline
//! produces for that request alone, and a poisoned neighbor must never leak
//! into anyone else's result.
//!
//! The property test draws random scalar programs (ptest `Expr`: smooth
//! unary ops and `+`/`-`/`*`) and random client interleavings, then compares
//! every served response against the sequential per-example oracle with
//! `f64::to_bits` equality. Scalar elementwise programs evaluate with the
//! same f64 operation sequence per lane in the scalar VM path and in the
//! vmapped tensor kernels, so bit-identity — not just tolerance — is the
//! right bar. One invalid request is injected per round to keep the
//! rejection/fallback machinery under the same microscope.

use myia::prelude::*;
use myia::ptest::{self, Config};
use myia::serve::error::ServeError;
use myia::tensor::Tensor;
use myia::types::AType;
use std::sync::Arc;
use std::time::Duration;

/// Bitwise equality for served values: exact f64 bits, recursively.
fn bit_eq(got: &Value, want: &Value) -> Result<(), String> {
    match (got, want) {
        (Value::F64(a), Value::F64(b)) => {
            if a.to_bits() == b.to_bits() {
                Ok(())
            } else {
                Err(format!("f64 bits differ: {a:?} vs {b:?}"))
            }
        }
        (Value::I64(a), Value::I64(b)) if a == b => Ok(()),
        (Value::Tensor(a), Value::Tensor(b)) => {
            if a.shape() != b.shape() {
                return Err(format!("shapes differ: {:?} vs {:?}", a.shape(), b.shape()));
            }
            let (av, bv) = (a.as_f64_vec(), b.as_f64_vec());
            for (x, y) in av.iter().zip(bv.iter()) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("tensor lanes differ: {x:?} vs {y:?}"));
                }
            }
            Ok(())
        }
        (Value::Tuple(a), Value::Tuple(b)) if a.len() == b.len() => {
            for (x, y) in a.iter().zip(b.iter()) {
                bit_eq(x, y)?;
            }
            Ok(())
        }
        _ => Err(format!("kinds differ: {} vs {}", got.type_name(), want.type_name())),
    }
}

/// Random programs × random interleavings: every served response is
/// bit-identical to the sequential oracle, with one invalid request injected
/// per round. Rounds alternate between a signature-specialized server (the
/// invalid request dies at admission) and a generic server (the invalid
/// request is a shape poison that forces the fallback path mid-batch).
#[test]
fn prop_serving_is_bit_identical_to_sequential_oracle() {
    ptest::check_exprs(Config { cases: 18, seed: 0x5E4E_D0C5 }, 4, |expr, rng| {
        let src = format!("def main(x):\n    return {expr}\n");
        let engine = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let oracle =
            engine.trace("main").and_then(|f| f.compile()).map_err(|e| e.to_string())?;
        let specialized = rng.below(2) == 0;
        let cfg = ServerConfig {
            max_batch: [2, 4, 8][rng.below(3)],
            max_wait: Duration::from_millis(4),
            queue_capacity: 64,
            workers: 1 + rng.below(2),
            full_policy: FullPolicy::Block,
        };
        let request_sig = specialized.then(|| vec![AType::F64]);
        let server = Server::for_entry(&engine, "main", vec![], request_sig, cfg, |f| f)
            .map_err(|e| e.to_string())?;
        let server = Arc::new(server);

        // Draw the whole schedule up front so it is seed-determined.
        let clients = 4 + rng.below(8);
        let inputs: Vec<Vec<f64>> = (0..clients)
            .map(|_| (0..1 + rng.below(3)).map(|_| ptest::gen_value(rng)).collect())
            .collect();
        let delays: Vec<u64> = (0..clients).map(|_| rng.below(3) as u64).collect();
        // The injected invalid request for this round.
        let poison: Value = if specialized {
            Value::str("not a number") // wrong type: must die at admission
        } else {
            // [2]-shaped tensor among scalars: stacks refuse, batch falls
            // back per-example; the generic pipeline still evaluates it
            // elementwise, so its own result must match the oracle too.
            Value::Tensor(Tensor::from_f64(&[ptest::gen_value(rng), ptest::gen_value(rng)]))
        };

        let (results, poison_result) = std::thread::scope(|s| {
            let handles: Vec<_> = inputs
                .iter()
                .zip(&delays)
                .map(|(xs, &d)| {
                    let server = server.clone();
                    s.spawn(move || {
                        std::thread::sleep(Duration::from_millis(d));
                        xs.iter()
                            .map(|&x| (x, server.submit(vec![Value::F64(x)])))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            let p = {
                let server = server.clone();
                let poison = poison.clone();
                s.spawn(move || server.submit(vec![poison]))
            };
            let results: Vec<Vec<(f64, Result<Value, ServeError>)>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            (results, p.join().unwrap())
        });

        // Every valid request: bit-identical to the sequential oracle.
        let mut served = 0u64;
        for row in &results {
            for (x, r) in row {
                let got = match r {
                    Ok(v) => v,
                    Err(e) => return Err(format!("x = {x} failed: {e}")),
                };
                let want = oracle.call(vec![Value::F64(*x)]).map_err(|e| e.to_string())?;
                bit_eq(got, &want).map_err(|e| format!("x = {x}: {e}"))?;
                served += 1;
            }
        }
        // The poison request: rejected at admission (specialized) or served
        // its own correct result via the fallback path (generic).
        let m = server.metrics();
        if specialized {
            match &poison_result {
                Err(ServeError::Rejected(_)) => {}
                other => return Err(format!("poison not rejected: {other:?}")),
            }
            if m.rejected_invalid != 1 {
                return Err(format!("rejected_invalid = {}", m.rejected_invalid));
            }
        } else {
            let got = poison_result.map_err(|e| format!("tensor poison failed: {e}"))?;
            let want = oracle.call(vec![poison]).map_err(|e| e.to_string())?;
            bit_eq(&got, &want).map_err(|e| format!("tensor poison: {e}"))?;
            served += 1;
        }
        if m.completed != served {
            return Err(format!("completed {} != served {served}", m.completed));
        }
        if m.batched_examples + m.direct_calls + m.fallback_examples != served {
            return Err(format!(
                "dispatch accounting off: {} batched + {} direct + {} fallback != {served}",
                m.batched_examples, m.direct_calls, m.fallback_examples
            ));
        }
        Ok(())
    });
}

/// Poison isolation, deterministic variant: when every request has a
/// *different* tensor shape, no two can ever stack, so the vmapped path can
/// never serve a multi-request batch — yet every response must still be
/// bit-identical to the oracle. This pins the fallback path open regardless
/// of timing.
#[test]
fn heterogeneous_shapes_never_poison_each_other() {
    let src = "def main(x):\n    return sin(x) * x + 1.0\n";
    let engine = Engine::from_source(src).unwrap();
    let oracle = engine.trace("main").unwrap().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(50),
        workers: 1,
        ..ServerConfig::default()
    };
    let server =
        Arc::new(Server::for_entry(&engine, "main", vec![], None, cfg, |f| f).unwrap());
    let results: Vec<(Tensor, Result<Value, ServeError>)> = std::thread::scope(|s| {
        (1..=8usize)
            .map(|n| {
                let server = server.clone();
                s.spawn(move || {
                    let data: Vec<f64> = (0..n).map(|i| 0.1 * (n * 10 + i) as f64).collect();
                    let t = Tensor::from_f64(&data);
                    let r = server.submit(vec![Value::Tensor(t.clone())]);
                    (t, r)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (t, r) in results {
        let got = r.unwrap();
        let want = oracle.call(vec![Value::Tensor(t)]).unwrap();
        bit_eq(&got, &want).unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.completed, 8);
    assert_eq!(m.failed, 0);
    assert_eq!(
        m.batched_batches, 0,
        "no two distinct-shape requests can stack; every multi-request batch must fall back"
    );
    assert_eq!(m.direct_calls + m.fallback_examples, 8);
}

/// Poison isolation, exec-failure branch: the *batched* executable itself
/// fails at run time (sabotaged with `raise_`), so every multi-request batch
/// takes the per-example fallback — and every caller still gets the exact
/// unbatched result. This is the hard acceptance case: a batch-level
/// execution failure must cost throughput, never correctness.
#[test]
fn batched_exec_failure_falls_back_per_example() {
    let src = "def main(x):\n    return x * 3.0 + 1.0\n\
               \ndef boom(x):\n    return raise_(\"deliberate batched failure\")\n";
    let engine = Engine::from_source(src).unwrap();
    let fallback = engine.trace("main").unwrap().compile().unwrap();
    let sabotaged = engine.trace("boom").unwrap().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(50),
        workers: 1,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(sabotaged, fallback, vec![], cfg).unwrap());
    let results: Vec<(f64, Result<Value, ServeError>)> = std::thread::scope(|s| {
        (0..8)
            .map(|i| {
                let server = server.clone();
                s.spawn(move || {
                    let x = 0.5 * i as f64 - 2.0;
                    (x, server.submit(vec![Value::F64(x)]))
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (x, r) in results {
        match r.unwrap() {
            Value::F64(v) => assert_eq!(v.to_bits(), (x * 3.0 + 1.0).to_bits(), "x = {x}"),
            other => panic!("{other}"),
        }
    }
    let m = server.metrics();
    assert_eq!(m.completed, 8);
    assert_eq!(m.failed, 0);
    assert_eq!(m.batched_batches, 0, "the sabotaged batched artifact can never succeed");
    assert_eq!(m.direct_calls + m.fallback_examples, 8);
}

/// A failing *request* (not a failing batch) gets its own `Exec` error and
/// nothing else: neighbors in the same storm of submissions all succeed.
#[test]
fn failing_request_gets_its_own_error() {
    // `item` demands a single-element tensor: [1] requests succeed, the [3]
    // poison fails in both the batched and the unbatched pipeline.
    let src = "def main(x):\n    return item(x) * 2.0\n";
    let engine = Engine::from_source(src).unwrap();
    let oracle = engine.trace("main").unwrap().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(30),
        workers: 1,
        ..ServerConfig::default()
    };
    let server =
        Arc::new(Server::for_entry(&engine, "main", vec![], None, cfg, |f| f).unwrap());
    let poison = Tensor::from_f64(&[1.0, 2.0, 3.0]);
    assert!(oracle.call(vec![Value::Tensor(poison.clone())]).is_err(), "poison must fail solo");
    let (goods, bad) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..7)
            .map(|i| {
                let server = server.clone();
                s.spawn(move || {
                    let x = 0.3 * i as f64 + 0.1;
                    (x, server.submit(vec![Value::Tensor(Tensor::from_f64(&[x]))]))
                })
            })
            .collect();
        let bad = {
            let server = server.clone();
            let poison = poison.clone();
            s.spawn(move || server.submit(vec![Value::Tensor(poison)]))
        };
        let goods: Vec<(f64, Result<Value, ServeError>)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        (goods, bad.join().unwrap())
    });
    match bad {
        Err(ServeError::Exec(msg)) => {
            assert!(msg.contains("item"), "error should name the failing op: {msg}")
        }
        other => panic!("poison request must fail with Exec, got {other:?}"),
    }
    for (x, r) in goods {
        let got = r.unwrap_or_else(|e| panic!("neighbor x = {x} poisoned: {e}"));
        let want = oracle.call(vec![Value::Tensor(Tensor::from_f64(&[x]))]).unwrap();
        bit_eq(&got, &want).unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.completed, 7);
    assert_eq!(m.failed, 1);
}

/// Shared (broadcast) arguments: serve per-example predictions of a model
/// whose weights are bound once at server construction, batched along the
/// request axis only.
#[test]
fn shared_weights_are_broadcast_not_batched() {
    let src = "def main(w, x):\n    return sum(w * x)\n";
    let engine = Engine::from_source(src).unwrap();
    let w = Tensor::from_f64(&[0.5, -1.0, 2.0]);
    let oracle = engine.trace("main").unwrap().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(20),
        ..ServerConfig::default()
    };
    let server = Arc::new(
        Server::for_entry(
            &engine,
            "main",
            vec![Value::Tensor(w.clone())],
            Some(vec![AType::Tensor { dtype: myia::tensor::DType::F64, shape: vec![Some(3)] }]),
            cfg,
            |f| f,
        )
        .unwrap(),
    );
    assert_eq!(server.request_arity(), 1, "shared weight is bound, not submitted");
    let results: Vec<(Tensor, Result<Value, ServeError>)> = std::thread::scope(|s| {
        (0..8)
            .map(|i| {
                let server = server.clone();
                s.spawn(move || {
                    let x = Tensor::from_f64(&[i as f64, 0.5 * i as f64, -0.25 * i as f64]);
                    let r = server.submit(vec![Value::Tensor(x.clone())]);
                    (x, r)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (x, r) in results {
        let got = r.unwrap();
        let want = oracle
            .call(vec![Value::Tensor(w.clone()), Value::Tensor(x)])
            .unwrap();
        bit_eq(&got, &want).unwrap();
    }
    // Wrong request shape dies at admission against the stored signature.
    match server.submit(vec![Value::Tensor(Tensor::from_f64(&[1.0, 2.0]))]) {
        Err(ServeError::Rejected(msg)) => assert!(msg.contains("expected"), "{msg}"),
        other => panic!("{other:?}"),
    }
}

/// Served gradients: the pipeline closure applies `.grad()` to both the
/// fallback and the vmapped artifact, so the server coalesces per-example
/// gradient requests the same way it coalesces forward passes.
#[test]
fn serves_gradients_bit_identical_to_unbatched_grad() {
    let src = "def main(x):\n    return sin(x) * x + tanh(x)\n";
    let engine = Engine::from_source(src).unwrap();
    let grad_oracle = engine.trace("main").unwrap().grad().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(20),
        ..ServerConfig::default()
    };
    let server = Arc::new(
        Server::for_entry(&engine, "main", vec![], Some(vec![AType::F64]), cfg, |f| f.grad())
            .unwrap(),
    );
    let results: Vec<(f64, Result<Value, ServeError>)> = std::thread::scope(|s| {
        (0..12)
            .map(|i| {
                let server = server.clone();
                s.spawn(move || {
                    let x = 0.25 * i as f64 - 1.5;
                    (x, server.submit(vec![Value::F64(x)]))
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (x, r) in results {
        let got = r.unwrap();
        let want = grad_oracle.call(vec![Value::F64(x)]).unwrap();
        bit_eq(&got, &want).unwrap_or_else(|e| panic!("grad at x = {x}: {e}"));
    }
    let m = server.metrics();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed + m.rejected_invalid, 0);
}

/// Close/drain race regression: shut the server down while 16 submitter
/// threads are hammering it. The contract is that every accepted `submit`
/// gets an answer — a bit-correct value or `Shutdown` — and never hangs on
/// a stranded response slot. The test completing at all proves no slot was
/// dropped without a fill; the accounting check proves no response was
/// fabricated either.
#[test]
fn close_under_load_answers_every_accepted_request() {
    let src = "def main(x):\n    return sin(x) * x + 1.0\n";
    let engine = Engine::from_source(src).unwrap();
    let oracle = engine.trace("main").unwrap().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_capacity: 8, // small, so submitters block on backpressure mid-close
        workers: 2,
        full_policy: FullPolicy::Block,
    };
    let server =
        Arc::new(Server::for_entry(&engine, "main", vec![], None, cfg, |f| f).unwrap());

    let outcomes: Vec<Vec<(f64, Result<Value, ServeError>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..16usize)
            .map(|c| {
                let server = server.clone();
                s.spawn(move || {
                    (0..50)
                        .map(|i| {
                            let x = 0.01 * (c * 50 + i) as f64 - 2.0;
                            (x, server.submit(vec![Value::F64(x)]))
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        // Shut down mid-flight, while queues are full and batches in-progress.
        std::thread::sleep(Duration::from_millis(5));
        server.shutdown();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut ok = 0u64;
    let mut shut_down = 0u64;
    for (x, r) in outcomes.iter().flatten() {
        match r {
            Ok(got) => {
                // Accepted and served: must be the exact sequential answer.
                let want = oracle.call(vec![Value::F64(*x)]).unwrap();
                bit_eq(got, &want).unwrap_or_else(|e| panic!("x = {x}: {e}"));
                ok += 1;
            }
            Err(ServeError::Shutdown) | Err(ServeError::QueueFull) => shut_down += 1,
            Err(other) => panic!("x = {x}: unexpected error {other}"),
        }
    }
    assert_eq!(ok + shut_down, 16 * 50, "every submit must return");
    let m = server.metrics();
    assert_eq!(
        m.completed, ok,
        "served-response accounting must reconcile across the close"
    );
    assert_eq!(m.failed, 0, "no request may fail with Exec during a clean close");
}

/// Shutdown while the *fallback* path is hot: the batched artifact is
/// sabotaged with `raise_` so every multi-request batch degrades to
/// per-example recovery (and the circuit breaker trips open mid-storm), then
/// the server is closed with queues full and fallback re-runs in flight. The
/// contract: every accepted request gets exactly one terminal response — a
/// bit-correct value or `Shutdown` — even when the close lands between a
/// batch's failure and its per-example re-runs.
#[test]
fn shutdown_during_fallback_answers_every_accepted_request() {
    let src = "def main(x):\n    return x * 3.0 + 1.0\n\
               \ndef boom(x):\n    return raise_(\"deliberate batched failure\")\n";
    let engine = Engine::from_source(src).unwrap();
    let fallback = engine.trace("main").unwrap().compile().unwrap();
    let sabotaged = engine.trace("boom").unwrap().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_capacity: 8, // small, so submitters block on backpressure mid-close
        workers: 2,
        full_policy: FullPolicy::Block,
    };
    let server = Arc::new(Server::new(sabotaged, fallback, vec![], cfg).unwrap());

    let outcomes: Vec<Vec<(f64, Result<Value, ServeError>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8usize)
            .map(|c| {
                let server = server.clone();
                s.spawn(move || {
                    (0..40)
                        .map(|i| {
                            let x = 0.05 * (c * 40 + i) as f64 - 4.0;
                            (x, server.submit(vec![Value::F64(x)]))
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        // Close while fallback re-runs are mid-flight.
        std::thread::sleep(Duration::from_millis(5));
        server.shutdown();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut ok = 0u64;
    let mut shut_down = 0u64;
    for (x, r) in outcomes.iter().flatten() {
        match r {
            Ok(Value::F64(v)) => {
                assert_eq!(v.to_bits(), (x * 3.0 + 1.0).to_bits(), "x = {x}");
                ok += 1;
            }
            Ok(other) => panic!("x = {x}: unexpected value {other}"),
            Err(ServeError::Shutdown) | Err(ServeError::QueueFull) => shut_down += 1,
            Err(other) => panic!("x = {x}: unexpected error {other}"),
        }
    }
    assert_eq!(ok + shut_down, 8 * 40, "every submit must return exactly once");
    let m = server.metrics();
    assert_eq!(m.completed, ok, "accounting must reconcile across the close");
    assert_eq!(m.failed, 0, "fallback must isolate the batch failure from every request");
    assert_eq!(m.batched_batches, 0, "the sabotaged batched artifact can never succeed");
}

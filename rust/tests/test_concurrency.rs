//! Thread-safety of the compile/run split: one `Arc<Executable>` hammered
//! by ≥8 threads must produce results bit-identical to a single-threaded
//! oracle, for both a grad pipeline and an XLA-lowered pipeline (whose lazy
//! per-shape segment cache is exercised concurrently).
//!
//! Run with `RUST_TEST_THREADS` unpinned so scheduling varies across runs —
//! these tests spawn their own threads and must pass under any
//! interleaving.

use myia::backend::Backend;
use myia::coordinator::{Engine, Executable};
use myia::serve::{Server, ServerConfig};
use myia::tensor::Tensor;
use myia::transform::Pipeline;
use myia::vm::{Program, Value};
use std::sync::Arc;

const THREADS: usize = 8;

/// Compile-time `Send + Sync` assertions: if any of these types loses
/// thread-safety (an `Rc`, a `RefCell`, a raw pointer without a SAFETY
/// argument), this test stops compiling.
#[test]
fn executable_program_and_value_are_send_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Executable>();
    assert_send_sync::<Arc<Executable>>();
    assert_send_sync::<Program>();
    assert_send_sync::<Value>();
    assert_send_sync::<Engine>();
    assert_send_sync::<Pipeline>();
    assert_send_sync::<Server>();
    assert_send_sync::<Arc<Server>>();
}

/// The serving front door under the same microscope as the raw executable:
/// many threads submitting through one `Server` must see exactly the
/// single-threaded oracle's bits, whatever batches the scheduler forms.
#[test]
fn eight_threads_through_one_server_match_sequential_oracle() {
    let src = "def f(x):\n    return sin(x) * exp(x) + tanh(x * x)\n";
    let e = Engine::from_source(src).unwrap();
    let oracle_exe: Arc<Executable> = e.trace("f").unwrap().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: std::time::Duration::from_millis(2),
        workers: 2,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::for_entry(&e, "f", vec![], None, cfg, |f| f).unwrap());

    let n = 100;
    let oracle: Vec<Vec<u64>> = (0..THREADS)
        .map(|t| {
            thread_inputs(t, n)
                .into_iter()
                .map(|x| scalar_bits(&oracle_exe.call(vec![Value::F64(x)]).unwrap()))
                .collect()
        })
        .collect();

    let results: Vec<Vec<u64>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let server = server.clone();
                s.spawn(move || {
                    thread_inputs(t, n)
                        .into_iter()
                        .map(|x| scalar_bits(&server.submit(vec![Value::F64(x)]).unwrap()))
                        .collect::<Vec<u64>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (t, (got, want)) in results.iter().zip(&oracle).enumerate() {
        assert_eq!(got, want, "thread {t}: served results diverged from oracle");
    }
    let m = server.metrics();
    assert_eq!(m.completed, (THREADS * n) as u64);
    assert_eq!(m.failed + m.rejected_invalid + m.rejected_full, 0);
}

/// Deterministic, per-thread-distinct scalar inputs.
fn thread_inputs(thread: usize, n: usize) -> Vec<f64> {
    (0..n).map(|i| 0.013 * (thread * n + i) as f64 - 1.3).collect()
}

fn scalar_bits(v: &Value) -> u64 {
    match v {
        Value::F64(x) => x.to_bits(),
        Value::Tensor(t) => t.item().expect("scalar result").to_bits(),
        other => panic!("expected scalar result, got {other}"),
    }
}

#[test]
fn eight_threads_on_one_grad_executable_match_sequential_oracle() {
    let src = "def f(x):\n    return sin(x) * exp(x) + tanh(x * x)\n";
    let e = Engine::from_source(src).unwrap();
    let f: Arc<Executable> = e.trace("f").unwrap().grad().compile().unwrap();

    let n = 200;
    // Single-threaded oracle first (exact f64 bits).
    let oracle: Vec<Vec<u64>> = (0..THREADS)
        .map(|t| {
            thread_inputs(t, n)
                .into_iter()
                .map(|x| scalar_bits(&f.call(vec![Value::F64(x)]).unwrap()))
                .collect()
        })
        .collect();

    let results: Vec<Vec<u64>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let f = f.clone();
                s.spawn(move || {
                    thread_inputs(t, n)
                        .into_iter()
                        .map(|x| scalar_bits(&f.call(vec![Value::F64(x)]).unwrap()))
                        .collect::<Vec<u64>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (t, (got, want)) in results.iter().zip(&oracle).enumerate() {
        assert_eq!(got, want, "thread {t}: concurrent grad results diverged from oracle");
    }
}

#[test]
fn eight_threads_on_one_xla_executable_match_sequential_oracle() {
    // Straight-line tensor program: lowers to an XLA segment whose lazy
    // per-shape cache is populated under concurrency (two distinct shapes,
    // so the RwLock'd signature cache sees real contention).
    let src = "def f(a, b):\n    return exp(a) * tanh(b) + a\n";
    let e = Engine::from_source(src).unwrap();
    let f: Arc<Executable> =
        e.trace("f").unwrap().jit(Backend::Xla).compile().unwrap();
    assert!(f.metrics.xla_segments >= 1, "expected at least one XLA segment");

    let arg = |t: usize, i: usize| -> Vec<Value> {
        let len = if (t + i) % 2 == 0 { 3 } else { 7 };
        let a: Vec<f64> = (0..len).map(|k| 0.1 * (t + k) as f64).collect();
        let b: Vec<f64> = (0..len).map(|k| 0.2 * (i + k) as f64 - 0.5).collect();
        vec![
            Value::Tensor(Tensor::from_f64(&a)),
            Value::Tensor(Tensor::from_f64(&b)),
        ]
    };
    let bits = |v: &Value| -> Vec<u64> {
        v.as_tensor()
            .expect("tensor result")
            .as_f64_vec()
            .into_iter()
            .map(f64::to_bits)
            .collect()
    };

    let n = 60;
    let oracle: Vec<Vec<Vec<u64>>> = (0..THREADS)
        .map(|t| (0..n).map(|i| bits(&f.call(arg(t, i)).unwrap())).collect())
        .collect();

    let results: Vec<Vec<Vec<u64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let f = f.clone();
                s.spawn(move || {
                    (0..n)
                        .map(|i| bits(&f.call(arg(t, i)).unwrap()))
                        .collect::<Vec<Vec<u64>>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (t, (got, want)) in results.iter().zip(&oracle).enumerate() {
        assert_eq!(got, want, "thread {t}: concurrent XLA results diverged from oracle");
    }
}

#[test]
fn mixed_pipelines_share_one_engine_across_threads() {
    // Different threads compile *and* run different pipelines against one
    // shared engine: the sharded artifact cache plus independent
    // executables must never interfere.
    let src = "\
def f(x):
    return x ** 3.0

def g(x):
    return sin(x) + x * x
";
    let e = Engine::from_source(src).unwrap();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let e = &e;
            s.spawn(move || {
                let (name, deriv): (&str, Box<dyn Fn(f64) -> f64>) = if t % 2 == 0 {
                    ("f", Box::new(|x| 3.0 * x * x))
                } else {
                    ("g", Box::new(|x| x.cos() + 2.0 * x))
                };
                let exe = e.trace(name).unwrap().grad().compile().unwrap();
                for i in 0..50 {
                    let x = 0.05 * (i as f64) - 1.0;
                    let got = exe.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap();
                    assert!(
                        (got - deriv(x)).abs() < 1e-9,
                        "thread {t} ({name}) at {x}: {got} vs {}",
                        deriv(x)
                    );
                }
            });
        }
    });
}

//! Forward-mode (`FwdTransform`) coverage over the tensor primitives:
//! finite-difference gradient checks for matmul, reductions, broadcasting,
//! softmax and the batching kernels — mirroring the reverse-mode checks in
//! `prop_random_programs.rs`, which until now left the ▷ rules for tensor
//! ops untested.

use myia::ad::forward::FwdTransform;
use myia::ir::Module;
use myia::parser::compile_source;
use myia::ptest;
use myia::tensor::{Rng, Tensor};
use myia::vm::{compile_program, Value, Vm};

/// Evaluate `entry` (a scalar-valued function of tensor arguments) in ▷
/// form: returns `(f(x), J·dx)` for the given primals and tangents.
fn jvp(src: &str, entry: &str, primals: &[Tensor], tangents: &[Tensor]) -> (f64, f64) {
    let mut m = Module::new();
    let graphs = compile_source(&mut m, src).unwrap();
    let g = graphs[entry];
    let mut fwd = FwdTransform::new();
    let fg = fwd.fwd_graph(&mut m, g).unwrap();
    m.validate().unwrap();
    let program = compile_program(&m, fg).unwrap();
    let vm = Vm::new(program);
    let args: Vec<Value> = primals
        .iter()
        .zip(tangents.iter())
        .map(|(x, dx)| {
            Value::tuple(vec![Value::Tensor(x.clone()), Value::Tensor(dx.clone())])
        })
        .collect();
    let out = vm.call_graph(fg, args).unwrap();
    let scalar_of = |v: &Value| -> Option<f64> {
        v.as_f64().or_else(|| v.as_tensor().and_then(|t| t.item().ok()))
    };
    match out {
        Value::Tuple(items) => (
            scalar_of(&items[0]).expect("scalar primal"),
            scalar_of(&items[1]).unwrap_or(0.0),
        ),
        other => panic!("expected (value, tangent), got {other}"),
    }
}

/// Evaluate the plain (untransformed) function.
fn call(src: &str, entry: &str, args: &[Tensor]) -> f64 {
    let vals = args.iter().map(|t| Value::Tensor(t.clone())).collect();
    let out = myia::coordinator::run_source(src, entry, vals).unwrap();
    out.as_f64()
        .or_else(|| out.as_tensor().and_then(|t| t.item().ok()))
        .unwrap()
}

/// Central finite difference of `f` along the direction `(d0..dn)`.
fn fd_directional(src: &str, entry: &str, primals: &[Tensor], tangents: &[Tensor]) -> f64 {
    let eps = 1e-6;
    let shift = |sign: f64| -> Vec<Tensor> {
        primals
            .iter()
            .zip(tangents.iter())
            .map(|(x, d)| {
                let xv = x.as_f64_vec();
                let dv = d.as_f64_vec();
                let shifted: Vec<f64> =
                    xv.iter().zip(dv.iter()).map(|(a, b)| a + sign * eps * b).collect();
                Tensor::from_f64_shaped(shifted, x.shape().to_vec()).unwrap()
            })
            .collect()
    };
    let fp = call(src, entry, &shift(1.0));
    let fm = call(src, entry, &shift(-1.0));
    (fp - fm) / (2.0 * eps)
}

fn check_jvp_matches_fd(src: &str, entry: &str, shapes: &[&[usize]], seed: u64) {
    let mut rng = Rng::new(seed);
    for round in 0..5 {
        let primals: Vec<Tensor> =
            shapes.iter().map(|s| rng.uniform_tensor(s, 0.2, 1.5)).collect();
        let tangents: Vec<Tensor> =
            shapes.iter().map(|s| rng.uniform_tensor(s, -1.0, 1.0)).collect();
        let (v, jv) = jvp(src, entry, &primals, &tangents);
        let direct = call(src, entry, &primals);
        assert!(
            (v - direct).abs() <= 1e-10 * (1.0 + direct.abs()),
            "{entry} round {round}: primal {v} vs direct {direct}"
        );
        let fd = fd_directional(src, entry, &primals, &tangents);
        ptest::close(jv, fd, 1e-4, &format!("{entry} jvp vs fd, round {round}"))
            .unwrap_or_else(|e| panic!("{e}"));
    }
}

#[test]
fn fwd_matmul_matches_fd() {
    let src = "def f(a, b):\n    return item(sum(matmul(a, b)))\n";
    check_jvp_matches_fd(src, "f", &[&[2, 3], &[3, 2]], 11);
    // nonlinear use of the product
    let src2 = "def g(a, b):\n    return item(sum(tanh(matmul(a, b))))\n";
    check_jvp_matches_fd(src2, "g", &[&[2, 2], &[2, 2]], 12);
}

#[test]
fn fwd_reductions_match_fd() {
    let src = "def f(w):\n    return item(sum(w * w))\n";
    check_jvp_matches_fd(src, "f", &[&[2, 3]], 21);
    let src2 = "def g(w):\n    return item(mean(exp(w)))\n";
    check_jvp_matches_fd(src2, "g", &[&[3, 2]], 22);
    let src3 = "def h(w):\n    return item(sum(sum_last_keep(w * w)))\n";
    check_jvp_matches_fd(src3, "h", &[&[2, 4]], 23);
}

#[test]
fn fwd_broadcasting_matches_fd() {
    // [2,3] ⊙ [3] exercises implicit broadcasting and its tangent.
    let src = "def f(a, b):\n    return item(sum(a * b + b))\n";
    check_jvp_matches_fd(src, "f", &[&[2, 3], &[3]], 31);
    let src2 = "def g(a, b):\n    return item(sum(sigmoid(a - b)))\n";
    check_jvp_matches_fd(src2, "g", &[&[2, 2], &[2]], 32);
}

#[test]
fn fwd_softmax_matches_fd() {
    let src = "def f(w):\n    return item(sum(softmax(w) * softmax(w)))\n";
    check_jvp_matches_fd(src, "f", &[&[2, 3]], 41);
}

#[test]
fn fwd_transpose_matches_fd() {
    let src = "def f(a, b):\n    return item(sum(matmul(transpose(a), b)))\n";
    check_jvp_matches_fd(src, "f", &[&[3, 2], &[3, 2]], 51);
}

#[test]
fn fwd_tangent_is_linear_in_direction() {
    // J·(3d) = 3·(J·d): the transform must be linear in the tangent slot.
    let src = "def f(w):\n    return item(sum(tanh(w * w)))\n";
    let mut rng = Rng::new(61);
    let x = rng.uniform_tensor(&[2, 3], 0.2, 1.5);
    let d = rng.uniform_tensor(&[2, 3], -1.0, 1.0);
    let d3 = myia::tensor::ops::mul(&d, &Tensor::scalar_f64(3.0)).unwrap();
    let (_, j1) = jvp(src, "f", &[x.clone()], &[d]);
    let (_, j3) = jvp(src, "f", &[x], &[d3]);
    assert!((j3 - 3.0 * j1).abs() < 1e-9, "{j3} vs {}", 3.0 * j1);
}

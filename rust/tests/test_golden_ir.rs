//! Golden-IR snapshot tests for the optimizer middle-end.
//!
//! The optimized adjoints of the paper's key programs are rendered with
//! `ir::print_graph` and pinned as text files under `tests/golden/`, so an
//! optimizer change shows up in review as a readable IR diff instead of a
//! silent node-count drift. The dead-graph GC makes this possible: it
//! renumbers the arena deterministically, so equal structure prints
//! identically across runs and machines.
//!
//! Blessing: a missing golden file is written on first run (and the test
//! passes, so fresh checkouts bootstrap); set `UPDATE_GOLDEN=1` to rewrite
//! snapshots after an intentional optimizer change — then commit the diff.
//!
//! Alongside the snapshots, these tests pin the three acceptance
//! invariants of the worklist middle-end:
//!   1. determinism: two fresh compiles print byte-identical IR;
//!   2. no artifact carries unreachable graphs (the GC postcondition);
//!   3. the new standard pipeline never produces more reachable nodes than
//!      the emulated pre-worklist optimizer (`LegacyOptimize`).

use myia::coordinator::mlp::MLP_SOURCE;
use myia::coordinator::{Engine, Executable};
use myia::ir::{analyze, print_graph};
use myia::opt::{LegacyOptimize, PassSet};
use myia::vm::Value;
use std::path::PathBuf;
use std::sync::Arc;

const FIG1_SRC: &str = "\
def f(x):
    return x ** 3.0

def main(x):
    return grad(f)(x)
";

const RECURSIVE_SRC: &str = "\
def tree_eval(depth, x, w):
    if depth == 0:
        return tanh(w * x)
    l = tree_eval(depth - 1, x * 0.9, w)
    r = tree_eval(depth - 1, x * 1.1, w)
    return tanh(w * (l + r))

def loss(w):
    return tree_eval(4, 1.0, w)

def main(w):
    return grad(loss)(w)
";

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(format!("{name}.ir"))
}

/// Compare `actual` against the committed snapshot; bless when asked to or
/// when the file does not exist yet.
fn assert_golden(name: &str, actual: &str) {
    let path = golden_path(name);
    let bless = std::env::var_os("UPDATE_GOLDEN").is_some() || !path.exists();
    if bless {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        eprintln!("blessed golden snapshot {}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        expected, actual,
        "optimized IR for `{name}` changed; inspect the diff above and re-bless \
         with UPDATE_GOLDEN=1 if intentional"
    );
}

/// Compile `entry` from `src` through the standard pipeline and the legacy
/// baseline; return both artifacts.
fn compile_both(src: &str, entry: &str) -> (Arc<Executable>, Arc<Executable>) {
    let e = Engine::from_source(src).unwrap();
    let new = e.trace(entry).unwrap().compile().unwrap();
    let legacy = e
        .trace(entry)
        .unwrap()
        .transform(LegacyOptimize)
        .optimize(PassSet::None) // drop the implicit standard optimize stage
        .compile()
        .unwrap();
    (new, legacy)
}

fn zero_unreachable(exe: &Executable) {
    let live = analyze(&exe.module, exe.entry).graphs.len();
    assert_eq!(
        exe.module.num_graphs(),
        live,
        "artifact carries {} graphs, only {live} reachable (GC postcondition broken)",
        exe.module.num_graphs()
    );
}

fn check_program(
    name: &str,
    src: &str,
    entry: &str,
    max_nodes: usize,
) -> (Arc<Executable>, Arc<Executable>) {
    let (new, legacy) = compile_both(src, entry);

    // 1. Determinism: a second fresh engine must print identical IR.
    let printed = print_graph(&new.module, new.entry, true);
    let again = Engine::from_source(src).unwrap().trace(entry).unwrap().compile().unwrap();
    assert_eq!(
        printed,
        print_graph(&again.module, again.entry, true),
        "`{name}`: optimized IR differs between two fresh compiles"
    );

    // 2. GC postcondition.
    zero_unreachable(&new);
    new.module.validate().unwrap();

    // 3. Never worse than the pre-worklist optimizer, and within the
    //    absolute budget the snapshot was taken at.
    let (nn, ln) = (new.metrics.nodes_after_optimize, legacy.metrics.nodes_after_optimize);
    assert!(nn <= ln, "`{name}`: new pipeline {nn} nodes vs legacy {ln}");
    assert!(nn <= max_nodes, "`{name}`: {nn} reachable nodes exceeds budget {max_nodes}\n{printed}");

    // 4. Snapshot (printed IR + reachable-node count, one reviewable file).
    let snapshot = format!("reachable nodes: {nn}\n\n{printed}");
    assert_golden(name, &snapshot);
    (new, legacy)
}

#[test]
fn fig1_adjoint_golden() {
    let (new, legacy) = check_program("fig1_adjoint", FIG1_SRC, "main", 24);
    // Both pipelines still compute 3x².
    for x in [0.5, -1.25, 2.0] {
        let a = new.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap();
        let b = legacy.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap();
        assert!((a - 3.0 * x * x).abs() < 1e-12, "x={x}: new pipeline returned {a}");
        assert!((a - b).abs() < 1e-12, "x={x}: pipelines disagree ({a} vs {b})");
    }
}

#[test]
fn recursive_adjoint_golden() {
    // The recursive tree adjoint: graphs must survive as calls (recursion
    // can't inline) but the module must stay compact and deterministic.
    let (new, legacy) = check_program("recursive_adjoint", RECURSIVE_SRC, "main", 1500);
    let w = 0.37;
    let a = new.call(vec![Value::F64(w)]).unwrap().as_f64().unwrap();
    let b = legacy.call(vec![Value::F64(w)]).unwrap().as_f64().unwrap();
    assert!((a - b).abs() < 1e-9, "pipelines disagree: {a} vs {b}");
    // Finite-difference cross-check.
    let eng = Engine::from_source(RECURSIVE_SRC).unwrap();
    let loss = eng.trace("loss").unwrap().compile().unwrap();
    let eps = 1e-6;
    let f = |w: f64| loss.call(vec![Value::F64(w)]).unwrap().as_f64().unwrap();
    let fd = (f(w + eps) - f(w - eps)) / (2.0 * eps);
    assert!((a - fd).abs() < 1e-5, "adjoint {a} vs finite difference {fd}");
}

#[test]
fn mlp_value_and_grad_counts() {
    // The MLP value_and_grad artifact: no snapshot (tensors in the IR make
    // the text huge) but the same three invariants.
    let e = Engine::from_source(MLP_SOURCE).unwrap();
    let new = e.trace("mlp_loss").unwrap().value_and_grad().compile().unwrap();
    let legacy = e
        .trace("mlp_loss")
        .unwrap()
        .value_and_grad()
        .transform(LegacyOptimize)
        .optimize(PassSet::None)
        .compile()
        .unwrap();
    zero_unreachable(&new);
    new.module.validate().unwrap();
    let (nn, ln) = (new.metrics.nodes_after_optimize, legacy.metrics.nodes_after_optimize);
    assert!(nn <= ln, "MLP value_and_grad: new pipeline {nn} nodes vs legacy {ln}");

    let printed = print_graph(&new.module, new.entry, true);
    let again =
        Engine::from_source(MLP_SOURCE).unwrap().trace("mlp_loss").unwrap().value_and_grad().compile().unwrap();
    assert_eq!(
        printed,
        print_graph(&again.module, again.entry, true),
        "MLP value_and_grad: optimized IR differs between two fresh compiles"
    );
}

#[test]
fn fig1_fusion_golden() {
    // The Figure-1 adjoint with fusion on: the surviving elementwise ops
    // collapse into fused kernels, the artifact never has more reachable
    // nodes than the `opt=no-fusion` ablation, and the fused IR is pinned
    // as its own snapshot (the printed `fused[...]` program makes kernel
    // regressions reviewable as text).
    let e = Engine::from_source(FIG1_SRC).unwrap();
    let fused = e.trace("main").unwrap().compile().unwrap();
    let plain = e
        .trace("main")
        .unwrap()
        .optimize(PassSet::Without("fusion".to_string()))
        .compile()
        .unwrap();

    let kernels = myia::opt::count_fused_kernels(&fused.module, fused.entry);
    assert!(kernels >= 1, "fig1 adjoint carries no fused kernels");
    let groups: usize = fused
        .metrics
        .stages
        .iter()
        .flat_map(|s| s.detail.iter())
        .filter(|(k, _)| k == "fused_groups")
        .map(|(_, v)| *v)
        .sum();
    assert!(groups >= 1, "optimize stage reported no fused groups");
    assert!(
        fused.metrics.nodes_after_optimize <= plain.metrics.nodes_after_optimize,
        "fusion increased node count: {} vs {}",
        fused.metrics.nodes_after_optimize,
        plain.metrics.nodes_after_optimize
    );

    // Semantics unchanged, bit for bit.
    for x in [0.5, -1.25, 2.0] {
        let a = fused.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap();
        let b = plain.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap();
        assert_eq!(a, b, "x={x}");
        assert!((a - 3.0 * x * x).abs() < 1e-12);
    }

    let snapshot = format!(
        "fused kernels: {kernels}\nreachable nodes: {}\n\n{}",
        fused.metrics.nodes_after_optimize,
        print_graph(&fused.module, fused.entry, true)
    );
    assert_golden("fig1_fused", &snapshot);
}

#[test]
fn unoptimized_artifacts_keep_their_scaffolding() {
    // Sanity for the comparison itself: opt=none must not run the GC, so
    // its artifact still carries the source graphs — i.e. the GC invariant
    // above is a property of the standard pipeline, not of printing.
    let e = Engine::from_source(FIG1_SRC).unwrap();
    let unopt = e.trace("main").unwrap().optimize(PassSet::None).compile().unwrap();
    let live = analyze(&unopt.module, unopt.entry).graphs.len();
    assert!(unopt.module.num_graphs() > live, "opt=none unexpectedly compacted the module");
}

//! Integration: the JAX/Pallas AOT artifacts load and run through the Rust
//! PJRT runtime, and JAX's gradients agree with our J-transform's gradients
//! on the same MLP — the strongest cross-validation of the AD system.

use myia::runtime::artifacts::MlpArtifacts;
use myia::runtime::XlaRuntime;
use myia::tensor::{DType, Rng, Tensor};

fn artifacts_dir() -> &'static str {
    "artifacts"
}

fn load() -> (XlaRuntime, MlpArtifacts) {
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let arts = MlpArtifacts::load(&rt, artifacts_dir()).expect("run `make artifacts` first");
    (rt, arts)
}

fn batch(meta: &myia::runtime::artifacts::MlpMeta, seed: u64) -> (Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let x = rng.normal_tensor(&[meta.batch, meta.in_dim], 1.0).cast(DType::F32);
    let mut onehot = vec![0.0f64; meta.batch * meta.out_dim];
    for i in 0..meta.batch {
        onehot[i * meta.out_dim + rng.below(meta.out_dim)] = 1.0;
    }
    let y = Tensor::from_f64_shaped(onehot, vec![meta.batch, meta.out_dim])
        .unwrap()
        .cast(DType::F32);
    (x, y)
}

#[test]
fn artifact_forward_shapes() {
    let (_rt, arts) = load();
    let params = arts.meta.init_params(1);
    let (x, _) = batch(&arts.meta, 2);
    let mut args = params.clone();
    args.push(x);
    let outs = arts.forward.run(&args).unwrap();
    assert_eq!(outs[0].shape(), &[arts.meta.batch, arts.meta.out_dim]);
}

#[test]
fn artifact_train_step_decreases_loss() {
    let (_rt, arts) = load();
    let mut params = arts.meta.init_params(3);
    let (x, y) = batch(&arts.meta, 4);
    let (loss0, new) = arts.step(&params, &x, &y).unwrap();
    params = new;
    let mut last = loss0;
    for _ in 0..10 {
        let (l, new) = arts.step(&params, &x, &y).unwrap();
        params = new;
        last = l;
    }
    assert!(last < loss0, "loss {loss0} -> {last} did not decrease");
}

#[test]
fn jax_grads_match_finite_differences() {
    let (_rt, arts) = load();
    let params = arts.meta.init_params(5);
    let (x, y) = batch(&arts.meta, 6);
    let (loss, grads) = arts.loss_and_grads(&params, &x, &y).unwrap();
    assert!(loss.is_finite());
    assert_eq!(grads.len(), 6);
    // Central differences on b3[0] through the loss artifact.
    let eps = 1e-2f64; // f32 artifact → modest epsilon
    let b3 = params[5].as_f64_vec();
    for (delta, sign) in [(eps, 1.0), (-eps, -1.0f64)] {
        let _ = (delta, sign);
    }
    let mut bump = b3.clone();
    bump[0] += eps;
    let mut dent = b3.clone();
    dent[0] -= eps;
    let run_loss = |b3v: Vec<f64>| -> f64 {
        let mut p = params.clone();
        p[5] = Tensor::from_f64_shaped(b3v, vec![arts.meta.out_dim])
            .unwrap()
            .cast(DType::F32);
        let mut args = p;
        args.push(x.clone());
        args.push(y.clone());
        arts.loss.run(&args).unwrap()[0].item().unwrap()
    };
    let fd = (run_loss(bump) - run_loss(dent)) / (2.0 * eps);
    let g = grads[5].as_f64_vec()[0];
    assert!(
        (fd - g).abs() < 5e-3,
        "finite difference {fd} vs jax grad {g}"
    );
}

//! Shape-specializing kernel tier acceptance (PR 9).
//!
//! The plan tier's contract is *zero observable semantics*: a call
//! dispatched through a cached `KernelPlan` must be bit-identical to the
//! same call with the tier disabled (`Executable::set_specialization`), at
//! every pool size, from any number of threads, across mid-stream shape
//! changes. This suite drives randomly generated programs (the in-crate
//! `ptest` substrate, pinned seeds) through forward, `grad`, and
//! `grad`-then-`vmap` pipelines with the tier on and off, asserts the
//! `plans_compiled` / `plan_hits` / `plan_shape_misses` telemetry at each
//! transition, and pins the PR's bypass decision: rank-0 and batch-of-1
//! outputs take the plan path like any other shape (only non-numeric
//! values bypass).

use myia::coordinator::mlp::{self, params_value};
use myia::coordinator::{Engine, Executable};
use myia::opt::PassSet;
use myia::ptest;
use myia::tensor::{DType, Rng, Tensor};
use myia::vm::{pool, Value};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Pool size and `MYIA_SPECIALIZE` are process-global; tests that touch
/// either serialize here and restore on drop.
fn lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|p| p.into_inner())
}

struct RestoreSize {
    prev: usize,
}

impl RestoreSize {
    fn new() -> RestoreSize {
        RestoreSize { prev: pool::intra_op_threads() }
    }
}

impl Drop for RestoreSize {
    fn drop(&mut self) {
        pool::set_intra_op_threads(self.prev);
    }
}

/// Flatten a result to raw bit patterns (NaN-safe equality).
fn value_bits(v: &Value, out: &mut Vec<u64>) -> Result<(), String> {
    match v {
        Value::F64(x) => {
            out.push(x.to_bits());
            Ok(())
        }
        Value::Tensor(t) => {
            for x in t.as_f64_vec() {
                out.push(x.to_bits());
            }
            Ok(())
        }
        Value::Tuple(items) => {
            for i in items.iter() {
                value_bits(i, out)?;
            }
            Ok(())
        }
        Value::ZeroT => {
            out.push(0x5Eed_2e20);
            Ok(())
        }
        other => Err(format!("unexpected result kind {other}")),
    }
}

fn bits(v: &Value) -> Vec<u64> {
    let mut out = Vec::new();
    value_bits(v, &mut out).expect("flattenable result");
    out
}

/// Call three times — cold (plans compile), warm (plans hit), and with the
/// tier disabled (generic dispatch) — and require all three bit-identical.
/// Returns plan hits observed on the warm call.
fn specialized_matches_generic(
    exe: &Executable,
    args: &[Value],
    what: &str,
) -> Result<u64, String> {
    exe.set_specialization(true);
    let cold = exe.call(args.to_vec()).map_err(|e| format!("{what} (cold): {e}"))?;
    let before = exe.plan_stats();
    let warm = exe.call(args.to_vec()).map_err(|e| format!("{what} (warm): {e}"))?;
    let hits = exe.plan_stats().plan_hits - before.plan_hits;
    exe.set_specialization(false);
    let generic = exe.call(args.to_vec()).map_err(|e| format!("{what} (generic): {e}"))?;
    exe.set_specialization(true);
    if bits(&cold) != bits(&warm) {
        return Err(format!("{what}: warm (planned) call diverged from cold call"));
    }
    if bits(&cold) != bits(&generic) {
        return Err(format!("{what}: specialized result diverged from generic dispatch"));
    }
    Ok(hits)
}

#[test]
fn specialized_forward_matches_generic() {
    // Serialized like every test here: the env-var test's compile window
    // must never overlap a VM construction that expects the tier on.
    let _g = lock();
    let total_hits = std::sync::atomic::AtomicU64::new(0);
    ptest::check_exprs(ptest::Config { cases: 30, seed: 0x59EC_0001 }, 4, |expr, rng| {
        let src = format!("def f(x):\n    return {expr}\n");
        let e = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let exe = e
            .trace("f")
            .map_err(|e| e.to_string())?
            .optimize(PassSet::Standard)
            .compile()
            .map_err(|e| e.to_string())?;
        let mut trng = Rng::new(rng.below(1 << 30) as u64);
        let x = Value::Tensor(trng.normal_tensor(&[4099], 1.0));
        let hits = specialized_matches_generic(&exe, &[x], &format!("forward {expr}"))?;
        total_hits.fetch_add(hits, std::sync::atomic::Ordering::Relaxed);
        Ok(())
    });
    // Not every random program has a plan-eligible site (a bare `x` has no
    // prims at all), but across 30 cases the tier must have fired.
    assert!(
        total_hits.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "no warm call ever hit a cached plan"
    );
}

#[test]
fn specialized_grad_matches_generic() {
    let _g = lock();
    ptest::check_exprs(ptest::Config { cases: 20, seed: 0x59EC_0002 }, 4, |expr, rng| {
        let src = format!("def g(x):\n    return item(sum({expr}))\n");
        let e = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let exe = e
            .trace("g")
            .map_err(|e| e.to_string())?
            .grad()
            .optimize(PassSet::Standard)
            .compile()
            .map_err(|e| e.to_string())?;
        let mut trng = Rng::new(rng.below(1 << 30) as u64);
        let x = Value::Tensor(trng.normal_tensor(&[2053], 1.0));
        specialized_matches_generic(&exe, &[x], &format!("grad {expr}"))?;
        Ok(())
    });
}

#[test]
fn specialized_grad_vmap_matches_generic() {
    let _g = lock();
    ptest::check_exprs(ptest::Config { cases: 12, seed: 0x59EC_0003 }, 3, |expr, rng| {
        let src = format!("def g(x):\n    return item(sum({expr}))\n");
        let e = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let exe = e
            .trace("g")
            .map_err(|e| e.to_string())?
            .grad()
            .vmap_axes(vec![Some(0)])
            .optimize(PassSet::Standard)
            .compile()
            .map_err(|e| e.to_string())?;
        let mut trng = Rng::new(rng.below(1 << 30) as u64);
        let xb = Value::Tensor(trng.normal_tensor(&[4, 513], 1.0));
        specialized_matches_generic(&exe, &[xb], &format!("grad∘vmap {expr}"))?;
        Ok(())
    });
}

const CHAIN_SRC: &str = "\
def f(x):
    a = exp(neg(x)) * x
    b = tanh(a + 0.5) * 2.0
    return item(sum(relu(b - 0.25)))
";

#[test]
fn planned_dispatch_is_bit_identical_at_pool_sizes_1_and_8() {
    let _g = lock();
    let _r = RestoreSize::new();
    let e = Engine::from_source(CHAIN_SRC).unwrap();
    let exe =
        e.trace("f").unwrap().grad().optimize(PassSet::Standard).compile().unwrap();
    // 40_000 elements clears FUSED_PAR_MIN_ELEMS, so the planned fused loop
    // really splits into chunks at size 8.
    assert!(40_000 > pool::FUSED_PAR_MIN_ELEMS);
    let mut trng = Rng::new(11);
    let x = Value::Tensor(trng.normal_tensor(&[40_000], 1.0));

    pool::set_intra_op_threads(1);
    exe.set_specialization(false);
    let want = bits(&exe.call(vec![x.clone()]).unwrap());
    exe.set_specialization(true);

    for n in [1usize, 8] {
        pool::set_intra_op_threads(n);
        let before = exe.plan_stats();
        let a = exe.call(vec![x.clone()]).unwrap(); // compiles or hits
        let b = exe.call(vec![x.clone()]).unwrap(); // hits
        assert_eq!(bits(&a), want, "pool size {n}, first planned call");
        assert_eq!(bits(&b), want, "pool size {n}, warm planned call");
        let after = exe.plan_stats();
        assert!(
            after.plan_hits > before.plan_hits,
            "pool size {n}: no plan hits ({before:?} -> {after:?})"
        );
    }
}

#[test]
fn eight_threads_share_one_plan_cache() {
    let _g = lock();
    let _r = RestoreSize::new();
    pool::set_intra_op_threads(2);
    let meta = mlp::default_meta();
    let mut rng = Rng::new(7);
    let teacher = mlp::synth_teacher(&meta, &mut rng);
    let (x, y) = mlp::synth_batch(&meta, &mut rng, &teacher);
    let params: Vec<Tensor> =
        meta.init_params(5).into_iter().map(|t| t.cast(DType::F64)).collect();
    let (_e, _loss, grad_fn) = mlp::compile_mlp(false).expect("compile MLP");
    let grad_fn: Arc<Executable> = grad_fn;
    let args = vec![params_value(&params), Value::Tensor(x), Value::Tensor(y)];

    // Reference with the tier off, then one warm-up call to compile plans.
    grad_fn.set_specialization(false);
    let want = bits(&grad_fn.call(args.clone()).expect("reference"));
    grad_fn.set_specialization(true);
    let _ = grad_fn.call(args.clone()).expect("warm-up");
    let warm = grad_fn.plan_stats();
    assert!(warm.plans_compiled > 0, "MLP adjoint compiled no plans: {warm:?}");

    std::thread::scope(|s| {
        for _ in 0..8 {
            let grad_fn = grad_fn.clone();
            let args = args.clone();
            let want = &want;
            s.spawn(move || {
                for _ in 0..5 {
                    let out = grad_fn.call(args.clone()).expect("concurrent call");
                    assert_eq!(&bits(&out), want, "planned concurrent call diverged");
                }
            });
        }
    });
    let after = grad_fn.plan_stats();
    // Fixed shapes: the hammering hits cached plans and never recompiles.
    assert_eq!(
        after.plans_compiled, warm.plans_compiled,
        "fixed-shape serving recompiled plans: {warm:?} -> {after:?}"
    );
    assert!(
        after.plan_hits >= warm.plan_hits + 40,
        "8 threads x 5 calls produced too few plan hits: {warm:?} -> {after:?}"
    );
}

#[test]
fn shape_change_mid_stream_recompiles_then_hits() {
    let _g = lock();
    let e = Engine::from_source(CHAIN_SRC).unwrap();
    let exe =
        e.trace("f").unwrap().grad().optimize(PassSet::Standard).compile().unwrap();
    exe.set_specialization(true);
    let mut trng = Rng::new(23);
    let a = Value::Tensor(trng.normal_tensor(&[64], 1.0));
    let b = Value::Tensor(trng.normal_tensor(&[65], 1.0));

    let s0 = exe.plan_stats();
    exe.call(vec![a.clone()]).unwrap();
    let s1 = exe.plan_stats();
    assert!(s1.plans_compiled > s0.plans_compiled, "first shape compiled no plans");
    assert_eq!(s1.plan_shape_misses, s0.plan_shape_misses, "cold compile is not a shape miss");

    exe.call(vec![a.clone()]).unwrap();
    let s2 = exe.plan_stats();
    assert!(s2.plan_hits > s1.plan_hits, "repeat shape did not hit");
    assert_eq!(s2.plans_compiled, s1.plans_compiled, "repeat shape recompiled");

    exe.call(vec![b.clone()]).unwrap();
    let s3 = exe.plan_stats();
    assert!(s3.plan_shape_misses > s2.plan_shape_misses, "new shape was not a miss");
    assert!(s3.plans_compiled > s2.plans_compiled, "new shape compiled no plans");

    exe.call(vec![b]).unwrap();
    let s4 = exe.plan_stats();
    assert!(s4.plan_hits > s3.plan_hits, "second shape did not hit after recompile");

    // The first shape's plans are still cached alongside the second's.
    exe.call(vec![a]).unwrap();
    let s5 = exe.plan_stats();
    assert!(s5.plan_hits > s4.plan_hits, "original shape evicted");
    assert_eq!(s5.plans_compiled, s4.plans_compiled, "original shape recompiled");
}

#[test]
fn rank0_and_batch_of_1_take_the_plan_path() {
    let _g = lock();
    // Rank-0 output: a full reduction.
    let e = Engine::from_source("def f(x):\n    return sum(x * x)\n").unwrap();
    let exe = e.trace("f").unwrap().optimize(PassSet::Standard).compile().unwrap();
    let x = Value::Tensor(Tensor::from_f64(&[1.5, -2.0, 0.25]));
    let hits =
        specialized_matches_generic(&exe, &[x], "rank-0 reduction").unwrap();
    assert!(hits > 0, "rank-0 output bypassed the plan tier");

    // Batch-of-1 tensors: no size-based bypass either.
    let e = Engine::from_source("def g(x):\n    return x * x + 1.0\n").unwrap();
    let exe = e.trace("g").unwrap().optimize(PassSet::Standard).compile().unwrap();
    let x = Value::Tensor(Tensor::from_f64(&[3.0]));
    let hits = specialized_matches_generic(&exe, &[x], "batch-of-1").unwrap();
    assert!(hits > 0, "batch-of-1 output bypassed the plan tier");
}

#[test]
fn myia_specialize_env_var_disables_the_tier() {
    let _g = lock();
    std::env::set_var("MYIA_SPECIALIZE", "0");
    let e = Engine::from_source(CHAIN_SRC).unwrap();
    let exe =
        e.trace("f").unwrap().grad().optimize(PassSet::Standard).compile().unwrap();
    std::env::remove_var("MYIA_SPECIALIZE");

    assert!(!exe.vm.specialization_enabled(), "MYIA_SPECIALIZE=0 ignored");
    let mut trng = Rng::new(5);
    let x = Value::Tensor(trng.normal_tensor(&[256], 1.0));
    let want = bits(&exe.call(vec![x.clone()]).unwrap());
    let _ = exe.call(vec![x.clone()]).unwrap();
    let s = exe.plan_stats();
    assert_eq!(
        (s.plans_compiled, s.plan_hits, s.plan_shape_misses),
        (0, 0, 0),
        "disabled tier still counted: {s:?}"
    );

    // The runtime override re-arms the tier on the same artifact.
    exe.set_specialization(true);
    let a = exe.call(vec![x.clone()]).unwrap();
    let b = exe.call(vec![x]).unwrap();
    let s = exe.plan_stats();
    assert!(s.plans_compiled > 0 && s.plan_hits > 0, "{s:?}");
    assert_eq!(bits(&a), want);
    assert_eq!(bits(&b), want);
}

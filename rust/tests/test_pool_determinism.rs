//! Determinism of the intra-op worker pool (PR 7 acceptance).
//!
//! The pool's contract is *bit-identical results at every pool size*: chunk
//! boundaries are a pure function of shapes, reductions within a chunk stay
//! sequential, and the matmul k-loop is never split. This suite drives
//! randomly generated programs (via the in-crate `ptest` substrate, pinned
//! seeds) through forward execution, `grad`, and `grad`-then-`vmap` at pool
//! sizes 1, 2, and 8, comparing raw f64 bit patterns — plus a serving-style
//! test where 8 external threads hammer one `Arc<Executable>` while the
//! pool parallelizes inside every call.
//!
//! CI runs this binary twice: once normally and once with `MYIA_THREADS=1`
//! to cover the env-var initialization path end to end (the resize APIs
//! must still work from that starting point).

use myia::coordinator::mlp::{self, params_value};
use myia::coordinator::Engine;
use myia::opt::PassSet;
use myia::ptest;
use myia::tensor::{DType, Rng, Tensor};
use myia::vm::{pool, Value};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Pool size is process-global; every test here serializes on this lock and
/// restores the previous size on drop, so tests cannot observe each other's
/// resizes regardless of execution order.
fn lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|p| p.into_inner())
}

struct RestoreSize {
    prev: usize,
}

impl RestoreSize {
    fn new() -> RestoreSize {
        RestoreSize { prev: pool::intra_op_threads() }
    }
}

impl Drop for RestoreSize {
    fn drop(&mut self) {
        pool::set_intra_op_threads(self.prev);
    }
}

const POOL_SIZES: [usize; 3] = [1, 2, 8];

/// Flatten a result to raw bit patterns (NaN-safe equality).
fn value_bits(v: &Value, out: &mut Vec<u64>) -> Result<(), String> {
    match v {
        Value::F64(x) => {
            out.push(x.to_bits());
            Ok(())
        }
        Value::Tensor(t) => {
            for x in t.as_f64_vec() {
                out.push(x.to_bits());
            }
            Ok(())
        }
        Value::Tuple(items) => {
            for i in items.iter() {
                value_bits(i, out)?;
            }
            Ok(())
        }
        Value::ZeroT => {
            out.push(0x5Eed_2e20); // stable sentinel for the symbolic zero
            Ok(())
        }
        other => Err(format!("unexpected result kind {other}")),
    }
}

/// Run `exe` once per pool size and require every run to reproduce the
/// size-1 run bit for bit.
fn assert_identical_across_sizes(
    exe: &myia::coordinator::Executable,
    args: &[Value],
    what: &str,
) -> Result<(), String> {
    let mut reference: Option<Vec<u64>> = None;
    for &n in &POOL_SIZES {
        pool::set_intra_op_threads(n);
        let out = exe.call(args.to_vec()).map_err(|e| format!("{what}: {e}"))?;
        let mut bits = Vec::new();
        value_bits(&out, &mut bits)?;
        match &reference {
            None => reference = Some(bits),
            Some(want) => {
                if *want != bits {
                    return Err(format!(
                        "{what}: result at pool size {n} differs from pool size 1"
                    ));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn forward_is_bit_identical_across_pool_sizes() {
    let _g = lock();
    let _r = RestoreSize::new();
    // 40_000 elements clears FUSED_PAR_MIN_ELEMS, so the fused loop really
    // does split into chunks at sizes 2 and 8.
    assert!(40_000 > pool::FUSED_PAR_MIN_ELEMS);
    ptest::check_exprs(ptest::Config { cases: 12, seed: 0xD17E_C7 }, 3, |expr, rng| {
        let src = format!("def f(x):\n    return {expr}\n");
        let e = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let exe = e
            .trace("f")
            .map_err(|e| e.to_string())?
            .optimize(PassSet::Standard)
            .compile()
            .map_err(|e| e.to_string())?;
        let mut trng = Rng::new(rng.below(1 << 30) as u64);
        let x = Value::Tensor(trng.normal_tensor(&[40_000], 1.0));
        assert_identical_across_sizes(&exe, &[x], &format!("forward {expr}"))
    });
}

#[test]
fn grad_is_bit_identical_across_pool_sizes() {
    let _g = lock();
    let _r = RestoreSize::new();
    ptest::check_exprs(ptest::Config { cases: 10, seed: 0x9AD5 }, 3, |expr, rng| {
        let src = format!("def g(x):\n    return item(sum({expr}))\n");
        let e = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let exe = e
            .trace("g")
            .map_err(|e| e.to_string())?
            .grad()
            .optimize(PassSet::Standard)
            .compile()
            .map_err(|e| e.to_string())?;
        let mut trng = Rng::new(rng.below(1 << 30) as u64);
        let x = Value::Tensor(trng.normal_tensor(&[40_000], 1.0));
        assert_identical_across_sizes(&exe, &[x], &format!("grad {expr}"))
    });
}

#[test]
fn grad_then_vmap_is_bit_identical_across_pool_sizes() {
    let _g = lock();
    let _r = RestoreSize::new();
    ptest::check_exprs(ptest::Config { cases: 8, seed: 0x7A9B }, 3, |expr, rng| {
        let src = format!("def g(x):\n    return item(sum({expr}))\n");
        let e = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let exe = e
            .trace("g")
            .map_err(|e| e.to_string())?
            .grad()
            .vmap_axes(vec![Some(0)])
            .optimize(PassSet::Standard)
            .compile()
            .map_err(|e| e.to_string())?;
        let mut trng = Rng::new(rng.below(1 << 30) as u64);
        let xb = Value::Tensor(trng.normal_tensor(&[4, 16_384], 1.0));
        assert_identical_across_sizes(&exe, &[xb], &format!("grad∘vmap {expr}"))
    });
}

/// Serving shape: 8 external threads share one `Arc<Executable>` (the MLP
/// `value_and_grad`, whose matmuls clear `MATMUL_PAR_MIN_FLOPS`) while the
/// intra-op pool is at size 8. Every concurrent call must reproduce the
/// single-threaded, single-lane reference bit for bit.
#[test]
fn concurrent_serving_over_intra_op_pool_is_deterministic() {
    let _g = lock();
    let _r = RestoreSize::new();
    let meta = mlp::default_meta();
    let mut rng = Rng::new(7);
    let teacher = mlp::synth_teacher(&meta, &mut rng);
    let (x, y) = mlp::synth_batch(&meta, &mut rng, &teacher);
    let params: Vec<Tensor> =
        meta.init_params(5).into_iter().map(|t| t.cast(DType::F64)).collect();
    let (_e, _loss, grad_fn) = mlp::compile_mlp(false).expect("compile MLP");
    let args = vec![params_value(&params), Value::Tensor(x), Value::Tensor(y)];

    pool::set_intra_op_threads(1);
    let mut want = Vec::new();
    value_bits(&grad_fn.call(args.clone()).expect("reference"), &mut want).unwrap();

    pool::set_intra_op_threads(8);
    std::thread::scope(|s| {
        for _ in 0..8 {
            let grad_fn = grad_fn.clone();
            let args = args.clone();
            let want = &want;
            s.spawn(move || {
                for _ in 0..5 {
                    let out = grad_fn.call(args.clone()).expect("concurrent call");
                    let mut got = Vec::new();
                    value_bits(&out, &mut got).unwrap();
                    assert_eq!(
                        &got, want,
                        "concurrent result diverged from 1-lane sequential reference"
                    );
                }
            });
        }
    });
}

/// When CI sets `MYIA_THREADS`, the pool must have initialized from it (the
/// lock + restore discipline above guarantees the size observed here is the
/// initial one). Without the variable, it must match available parallelism.
#[test]
fn pool_size_respects_env_override() {
    let _g = lock();
    let n = pool::intra_op_threads();
    assert!((1..=pool::MAX_THREADS).contains(&n));
    if let Some(v) =
        std::env::var("MYIA_THREADS").ok().and_then(|s| s.trim().parse::<usize>().ok())
    {
        if v >= 1 {
            assert_eq!(n, v.min(pool::MAX_THREADS), "MYIA_THREADS override ignored");
        }
    }
}

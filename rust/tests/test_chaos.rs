//! Robustness acceptance tests: deadlines end-to-end, plus (under
//! `--features chaos`) deterministic fault-injection storms.
//!
//! The contract under test: **every accepted request terminates with either
//! a bit-identical result or a structured [`ServeError`]** — no hang, no
//! panic escape, no poisoned lock — whatever goes wrong underneath: a
//! runaway program, an injected primitive failure, a pool-task panic, a
//! delayed queue pop, or a flaky disk.
//!
//! The storm tests are compiled only with `--features chaos` (the library's
//! injection hooks are no-ops otherwise) and run under `MYIA_FAULT` seeds
//! pinned by the CI chaos job. Faults are scoped: oracles are always
//! computed in a cleared window, so a surviving `Ok` can be held to exact
//! bit equality.

use myia::prelude::*;
use myia::serve::error::ServeError;
use myia::types::AType;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Fault plans are process-global state; every test in this binary holds
/// this lock so plans never leak across concurrently running tests.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Take the fault lock and neutralize any ambient `MYIA_FAULT` plan: the
/// env plan installs itself lazily at the first instrumented site, so touch
/// one site first, then clear. Each test then opts into its own plan.
fn fault_quiet() -> MutexGuard<'static, ()> {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _ = myia::faultinject::fire(myia::faultinject::Site::PrimEval);
    myia::faultinject::clear();
    guard
}

/// Terminates for `x <= 0` (returning `x * 2 - 1`), spins forever for
/// `x > 0`: the canonical runaway request.
const SPIN_OR_SERVE: &str = "def main(x):\n\
                             \x20   while x > 0.0:\n\
                             \x20       x = x + 1.0\n\
                             \x20   return x * 2.0 - 1.0\n";

/// The headline acceptance case: a request that would never terminate is
/// served with a 50 ms deadline and comes back `DeadlineExceeded`, while
/// well-behaved requests on the same server keep returning results
/// bit-identical to the sequential oracle. The runaway must not pin a
/// worker forever, poison a lock, or distort any neighbor's answer.
#[test]
fn deadline_cuts_runaway_request_while_neighbors_serve() {
    let _g = fault_quiet();
    let engine = Engine::from_source(SPIN_OR_SERVE).unwrap();
    let oracle = engine.trace("main").unwrap().compile().unwrap();
    // Data-dependent control flow cannot be vmapped, so build the server
    // from two unbatched artifacts: any multi-request batch fails on the
    // stacked input and degrades to the per-example fallback, which is
    // exactly the layer the deadline budget must protect.
    let fallback = engine.trace("main").unwrap().compile().unwrap();
    let batched = engine.trace("main").unwrap().compile().unwrap();
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        queue_capacity: 32,
        workers: 2,
        full_policy: FullPolicy::Block,
    };
    let server = Arc::new(Server::new(batched, fallback, vec![], cfg).unwrap());

    let started = Instant::now();
    let (goods, runaway) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..6)
            .map(|c| {
                let server = server.clone();
                s.spawn(move || {
                    (0..5)
                        .map(|i| {
                            let x = -0.3 * (c * 5 + i + 1) as f64;
                            (x, server.submit(vec![Value::F64(x)]))
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let runaway = {
            let server = server.clone();
            s.spawn(move || {
                server.submit_with(
                    vec![Value::F64(1.0)],
                    SubmitOpts::timeout(Duration::from_millis(50)),
                )
            })
        };
        let goods: Vec<Vec<(f64, Result<Value, ServeError>)>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        (goods, runaway.join().unwrap())
    });

    match runaway {
        Err(ServeError::DeadlineExceeded) => {}
        other => panic!("runaway request must hit its deadline, got {other:?}"),
    }
    assert!(
        started.elapsed() < Duration::from_secs(30),
        "the runaway must be cut promptly, not ride a worker forever"
    );
    for (x, r) in goods.iter().flatten() {
        let got = r.as_ref().unwrap_or_else(|e| panic!("neighbor x = {x} failed: {e}"));
        match (got, oracle.call(vec![Value::F64(*x)]).unwrap()) {
            (Value::F64(a), Value::F64(b)) => {
                assert_eq!(a.to_bits(), b.to_bits(), "x = {x}")
            }
            (got, want) => panic!("x = {x}: {got} vs {want}"),
        }
    }
    let m = server.metrics();
    assert_eq!(m.completed, 30, "every well-behaved request must be served");
    assert!(m.deadline_expired >= 1, "the runaway must be counted:\n{m}");
    server.shutdown();
}

/// A deadline that has already passed is refused at admission — counted,
/// answered `DeadlineExceeded`, and never enqueued or executed — while an
/// unexpired deadline on the same server serves normally.
#[test]
fn expired_deadline_refused_at_admission() {
    let _g = fault_quiet();
    let engine = Engine::from_source("def main(x):\n    return x * x + 1.0\n").unwrap();
    let server = Server::for_entry(
        &engine,
        "main",
        vec![],
        Some(vec![AType::F64]),
        ServerConfig::default(),
        |f| f,
    )
    .unwrap();
    let past = Instant::now()
        .checked_sub(Duration::from_millis(5))
        .unwrap_or_else(Instant::now);
    match server.submit_with(vec![Value::F64(2.0)], SubmitOpts::deadline(past)) {
        Err(ServeError::DeadlineExceeded) => {}
        other => panic!("{other:?}"),
    }
    let m = server.metrics();
    assert_eq!(m.deadline_expired, 1);
    assert_eq!(m.completed, 0, "an expired request must never execute");

    match server.submit_with(vec![Value::F64(2.0)], SubmitOpts::timeout(Duration::from_secs(10)))
    {
        Ok(Value::F64(v)) => assert_eq!(v, 5.0),
        other => panic!("{other:?}"),
    }
    assert_eq!(server.metrics().completed, 1);
}

#[cfg(feature = "chaos")]
mod storm {
    use super::*;
    use myia::faultinject::{self, FaultKind, FaultPlan, Site};
    use myia::ptest::{self, Config};
    use myia::runtime::diskcache::{ArtifactKey, DiskCache};

    /// The chaos property: random programs × random client interleavings
    /// × an injected-fault plan covering every site class. Every submit
    /// must terminate with `Ok` **bit-identical to the fault-free oracle**
    /// or a structured `ServeError`; afterwards the server still snapshots
    /// metrics and shuts down cleanly (no hang, no panic escape, no
    /// poisoned lock). The plan comes from `MYIA_FAULT` when set (the CI
    /// chaos job pins three seeds) and a default all-site plan otherwise.
    #[test]
    fn chaos_storm_every_request_terminates_structurally() {
        let _g = fault_quiet();
        let plan = std::env::var("MYIA_FAULT")
            .ok()
            .and_then(|s| FaultPlan::parse(&s))
            .unwrap_or_else(|| FaultPlan::all(0xC4A0_5EED, 0.08));

        ptest::check_exprs(Config { cases: 10, seed: 0xC4A0_5EED }, 4, |expr, rng| {
            faultinject::clear();
            let src = format!("def main(x):\n    return {expr}\n");
            let engine = Engine::from_source(&src).map_err(|e| e.to_string())?;
            let oracle =
                engine.trace("main").and_then(|f| f.compile()).map_err(|e| e.to_string())?;
            let cfg = ServerConfig {
                max_batch: [2, 4, 8][rng.below(3)],
                max_wait: Duration::from_millis(3),
                queue_capacity: 16,
                workers: 1 + rng.below(2),
                full_policy: if rng.below(2) == 0 {
                    FullPolicy::Block
                } else {
                    FullPolicy::Reject
                },
            };
            let server = Server::for_entry(&engine, "main", vec![], None, cfg, |f| f)
                .map_err(|e| e.to_string())?;
            let server = Arc::new(server);

            // Draw the whole schedule, then the oracle bits, both with
            // injection OFF — `Ok` under faults is held to these bits.
            let clients = 4 + rng.below(5);
            let schedule: Vec<Vec<(f64, u64, bool)>> = (0..clients)
                .map(|_| {
                    (0..1 + rng.below(3))
                        .map(|_| {
                            (ptest::gen_value(rng), rng.below(3) as u64, rng.below(4) == 0)
                        })
                        .collect()
                })
                .collect();
            let mut want: Vec<Vec<u64>> = Vec::with_capacity(schedule.len());
            for row in &schedule {
                let mut bits = Vec::with_capacity(row.len());
                for (x, _, _) in row {
                    match oracle.call(vec![Value::F64(*x)]).map_err(|e| e.to_string())? {
                        Value::F64(v) => bits.push(v.to_bits()),
                        other => return Err(format!("oracle returned {other}")),
                    }
                }
                want.push(bits);
            }

            faultinject::install(plan.clone());
            let outcomes: Vec<Vec<(f64, Result<Value, ServeError>)>> =
                std::thread::scope(|s| {
                    schedule
                        .iter()
                        .map(|row| {
                            let server = server.clone();
                            s.spawn(move || {
                                row.iter()
                                    .map(|&(x, delay, tight)| {
                                        std::thread::sleep(Duration::from_millis(delay));
                                        let opts = if tight {
                                            SubmitOpts::timeout(Duration::from_millis(2))
                                        } else {
                                            SubmitOpts::default()
                                        };
                                        (x, server.submit_with(vec![Value::F64(x)], opts))
                                    })
                                    .collect::<Vec<_>>()
                            })
                        })
                        .collect::<Vec<_>>()
                        .into_iter()
                        .map(|h| h.join().unwrap())
                        .collect()
                });
            faultinject::clear();

            let mut submitted = 0u64;
            for (row, wrow) in outcomes.iter().zip(&want) {
                for ((x, r), wbits) in row.iter().zip(wrow) {
                    submitted += 1;
                    match r {
                        Ok(Value::F64(v)) => {
                            if v.to_bits() != *wbits {
                                return Err(format!(
                                    "x = {x}: fault-window success not bit-identical: \
                                     {v:?} vs {:?}",
                                    f64::from_bits(*wbits)
                                ));
                            }
                        }
                        Ok(other) => return Err(format!("x = {x}: non-scalar {other}")),
                        // Injection never makes a valid request invalid.
                        Err(ServeError::Rejected(msg)) => {
                            return Err(format!("x = {x}: valid request rejected: {msg}"))
                        }
                        // Every other variant is an acceptable structured
                        // outcome under injected faults.
                        Err(_) => {}
                    }
                }
            }
            // The stack must still be fully operational: metrics snapshot
            // (poison-free locks) and a clean drain.
            let m = server.metrics();
            if m.submitted != submitted {
                return Err(format!("submitted {} != {submitted}", m.submitted));
            }
            server.shutdown();
            Ok(())
        });
    }

    /// Disk-tier chaos: under a full-rate `disk_read` plan whose first four
    /// draws are all hard faults, a load exhausts its bounded retries and
    /// surfaces a structured error (the engine's cue to cold-compile) —
    /// never a panic — and the cache recovers the moment faults stop.
    #[test]
    fn chaos_disk_read_faults_exhaust_retries_then_recover() {
        let _g = fault_quiet();
        let dir = std::env::temp_dir().join(format!("myia-chaos-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = DiskCache::new(&dir).unwrap();
        let key = ArtifactKey {
            entry: "f".to_string(),
            pipeline_spec: "vm".to_string(),
            signature: "generic".to_string(),
            module_fp: 1,
        };
        assert!(cache.load(&key).unwrap().is_none(), "clean miss with no plan");
        assert_eq!(cache.take_retries(), 0);

        // Pick a seed whose first four disk_read draws are all errors or
        // panics (a latency draw would let the real read through): the
        // retry loop then deterministically exhausts its budget.
        let seed = (0u64..256)
            .find(|&s| {
                faultinject::install(FaultPlan::for_sites(s, 1.0, &[Site::DiskRead]));
                (0..4).all(|_| {
                    matches!(
                        faultinject::fire(Site::DiskRead),
                        Some(FaultKind::Error) | Some(FaultKind::Panic)
                    )
                })
            })
            .expect("some seed must draw four hard faults in a row");
        faultinject::install(FaultPlan::for_sites(seed, 1.0, &[Site::DiskRead]));
        let err = cache.load(&key).unwrap_err();
        assert!(err.contains("injected"), "{err}");
        assert_eq!(cache.take_retries(), 3, "exactly the bounded retry budget");

        faultinject::clear();
        assert!(cache.load(&key).unwrap().is_none(), "recovers once faults stop");
        assert_eq!(cache.take_retries(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! E5: higher-order derivatives via reverse-over-reverse (§3.2).
//!
//! "In order to ensure that our transformation can be applied again on the
//! transformed program (so we can use reverse-over-reverse to compute
//! second-order derivatives), it must be able to handle functions with free
//! variables." These tests apply `grad` up to three deep and compare against
//! closed forms. The tape baseline cannot do this at all (§2.1.2).

use myia::coordinator::Engine;
use myia::vm::Value;

fn run1(src: &str, x: f64) -> f64 {
    let s = Engine::from_source(src).unwrap();
    let f = s.trace("main").unwrap().compile().unwrap();
    match f.call(vec![Value::F64(x)]).unwrap() {
        Value::F64(v) => v,
        Value::Tensor(t) => t.item().unwrap(),
        other => panic!("{other}"),
    }
}

#[test]
fn second_derivative_of_cubic() {
    let src = "\
def f(x):
    return x ** 3.0

def df(x):
    return grad(f)(x)

def main(x):
    return grad(df)(x)
";
    // f'' = 6x
    for x in [0.5, 2.0, -1.25] {
        let d2 = run1(src, x);
        assert!((d2 - 6.0 * x).abs() < 1e-9, "x={x}: {d2}");
    }
}

#[test]
fn third_derivative() {
    let src = "\
def f(x):
    return x ** 4.0 + 2.0 * x ** 2.0

def d1(x):
    return grad(f)(x)

def d2(x):
    return grad(d1)(x)

def main(x):
    return grad(d2)(x)
";
    // f''' = 24x
    let d3 = run1(src, 1.5);
    assert!((d3 - 36.0).abs() < 1e-6, "{d3}");
}

#[test]
fn second_derivative_of_transcendental() {
    let src = "\
def f(x):
    return sin(x) * exp(x)

def df(x):
    return grad(f)(x)

def main(x):
    return grad(df)(x)
";
    // (sin·eˣ)'' = 2·cos(x)·eˣ
    let x = 0.7f64;
    let want = 2.0 * x.cos() * x.exp();
    let got = run1(src, x);
    assert!((got - want).abs() < 1e-9, "{got} vs {want}");
}

#[test]
fn hessian_diagonal_through_control_flow() {
    let src = "\
def f(x):
    if x > 0.0:
        return x ** 3.0
    else:
        return x ** 2.0

def df(x):
    return grad(f)(x)

def main(x):
    return grad(df)(x)
";
    assert!((run1(src, 2.0) - 12.0).abs() < 1e-9); // 6x on the cubic side
    assert!((run1(src, -2.0) - 2.0).abs() < 1e-9); // 2 on the quadratic side
}

#[test]
fn value_and_grad_composes_with_grad() {
    let src = "\
def f(x):
    return x ** 3.0

def g(x):
    vg = value_and_grad(f)(x)
    return vg[0] + vg[1]

def main(x):
    return grad(g)(x)
";
    // d/dx (x³ + 3x²) = 3x² + 6x
    let x = 1.2f64;
    let want = 3.0 * x * x + 6.0 * x;
    let got = run1(src, x);
    assert!((got - want).abs() < 1e-9, "{got} vs {want}");
}

#[test]
fn forward_over_reverse() {
    // jfwd of a grad-function: d²f/dx² through mixed modes.
    let src = "\
def f(x):
    return x ** 3.0

def df(x):
    return grad(f)(x)

def main(x):
    out = jfwd(df)(x, 1.0)
    return out[1]
";
    let got = run1(src, 2.0);
    assert!((got - 12.0).abs() < 1e-9, "{got}");
}

#[test]
fn tape_baseline_cannot_do_reverse_over_reverse() {
    use myia::baselines::tape;
    let tp = tape::Tape::new();
    let x = tape::scalar(&tp, 2.0);
    let y = x.mul(&x).mul(&x);
    let _ = y.backward().unwrap();
    // The limitation is documented and explicit (checked in unit tests);
    // here we assert the API surface exists and the first backward works.
    assert!((y.value().as_f64().unwrap() - 8.0).abs() < 1e-12);
}

//! Fusion + unique-buffer-reuse acceptance suite.
//!
//! * property: random expression programs (pinned seeds, shrinker-minimized
//!   failures via the ptest artifact path) are **bit-identical** between the
//!   standard pipeline (fusion on) and the `opt=no-fusion` ablation — for
//!   forward values, for gradients, on tensors and on scalars;
//! * counters: a fused elementwise chain executes as one `fused_map` with
//!   zero intermediate tensor allocations and zero `as_f64_vec`-style
//!   round-trips (`ExecStats::{fused_ops, allocs_saved, conversions}`);
//! * dtype: typed kernels preserve i64 exactly (values above 2^53, where
//!   the old f64 round-trip silently lost precision);
//! * aliasing: the same tensor as both operands, shared (refcount > 1)
//!   operands, and 8 threads on one `Arc<Executable>` all stay correct with
//!   in-place reuse enabled;
//! * caching: pipeline fingerprints without fusion are unchanged.

use myia::coordinator::mlp::{
    default_meta, params_value, synth_batch, synth_teacher, MLP_SOURCE,
};
use myia::coordinator::{Engine, Executable};
use myia::opt::PassSet;
use myia::ptest::{check_exprs, gen_value, Config};
use myia::tensor::{buffer_reuse_count, ops, DType, Tensor};
use myia::transform::Pipeline;
use myia::vm::Value;
use std::sync::Arc;

fn no_fusion() -> PassSet {
    PassSet::Without("fusion".to_string())
}

/// Compile `entry` with and without the fusion pass.
fn compile_pair(src: &str, entry: &str) -> (Arc<Executable>, Arc<Executable>) {
    let e = Engine::from_source(src).unwrap();
    let fused = e.trace(entry).unwrap().optimize(PassSet::Standard).compile().unwrap();
    let plain = e.trace(entry).unwrap().optimize(no_fusion()).compile().unwrap();
    (fused, plain)
}

/// Count `fused_map` applications reachable from the artifact's entry.
fn fused_kernels(exe: &Executable) -> usize {
    myia::opt::count_fused_kernels(&exe.module, exe.entry)
}

const CHAIN_SRC: &str = "\
def f(x):
    a = exp(neg(x)) * x
    b = tanh(a + 0.5) * 2.0
    c = relu(b - 0.25)
    return sigmoid(c) + a
";

#[test]
fn fused_chain_is_bit_identical_and_allocation_free() {
    let (fused, plain) = compile_pair(CHAIN_SRC, "f");
    assert!(fused_kernels(&fused) >= 1, "standard pipeline produced no fused kernels");
    assert_eq!(fused_kernels(&plain), 0, "no-fusion arm must carry none");

    let x = Value::Tensor(Tensor::from_f64(&[0.3, -1.7, 2.2, 0.0, 5.5]));
    let _ = fused.vm.take_stats();
    let a = fused.call(vec![x.clone()]).unwrap();
    let stats = fused.vm.take_stats();
    let b = plain.call(vec![x]).unwrap();
    assert!(a.structural_eq(&b), "fused {a} vs unfused {b}");

    assert!(stats.fused_ops >= 1, "{stats:?}");
    // Zero intermediate tensors inside fused regions: every interior op of
    // every fused kernel is reported as an avoided allocation.
    assert!(stats.allocs_saved >= 4, "{stats:?}");
    // Zero dtype round-trips anywhere on this elementwise program: the
    // typed kernels and the fused loop never materialize an f64 view.
    assert_eq!(stats.conversions, 0, "{stats:?}");
}

#[test]
fn property_fused_matches_no_fusion_forward_and_grad() {
    // Pinned seeds; failures are shrinker-minimized and written to the
    // ptest artifact dir for CI upload (same path as the other suites).
    check_exprs(Config { cases: 40, seed: 0xF05E_D001 }, 4, |expr, rng| {
        let src = format!("def f(x):\n    return {expr}\n");
        let e = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let fused = e
            .trace("f")
            .unwrap()
            .optimize(PassSet::Standard)
            .compile()
            .map_err(|e| e.to_string())?;
        let plain = e
            .trace("f")
            .unwrap()
            .optimize(no_fusion())
            .compile()
            .map_err(|e| e.to_string())?;

        // Tensor input (exercises the monomorphized fused loop)...
        let xs: Vec<f64> = (0..7).map(|_| gen_value(rng)).collect();
        let tv = Value::Tensor(Tensor::from_f64(&xs));
        let a = fused.call(vec![tv.clone()]).map_err(|e| e.to_string())?;
        let b = plain.call(vec![tv]).map_err(|e| e.to_string())?;
        if !a.structural_eq(&b) {
            return Err(format!("tensor forward diverged: {a} vs {b}"));
        }
        // ...and scalar input (exercises the exact replay path).
        let s = Value::F64(gen_value(rng));
        let a = fused.call(vec![s.clone()]).map_err(|e| e.to_string())?;
        let b = plain.call(vec![s]).map_err(|e| e.to_string())?;
        if !a.structural_eq(&b) {
            return Err(format!("scalar forward diverged: {a} vs {b}"));
        }

        // Gradients: fuse inside the expanded adjoint, compare bitwise.
        let gsrc = format!(
            "def f(x):\n    return {expr}\n\ndef loss(x):\n    return item(sum(f(x)))\n"
        );
        let ge = Engine::from_source(&gsrc).map_err(|e| e.to_string())?;
        let gf = ge
            .trace("loss")
            .unwrap()
            .grad()
            .optimize(PassSet::Standard)
            .compile()
            .map_err(|e| e.to_string())?;
        let gp = ge
            .trace("loss")
            .unwrap()
            .grad()
            .optimize(no_fusion())
            .compile()
            .map_err(|e| e.to_string())?;
        let tv = Value::Tensor(Tensor::from_f64(&xs));
        let a = gf.call(vec![tv.clone()]).map_err(|e| e.to_string())?;
        let b = gp.call(vec![tv]).map_err(|e| e.to_string())?;
        if !a.structural_eq(&b) {
            return Err(format!("gradient diverged: {a} vs {b}"));
        }
        Ok(())
    });
}

#[test]
fn mlp_value_and_grad_bit_identical_with_fusion() {
    let mut rng = myia::tensor::Rng::new(23);
    let meta = default_meta();
    let teacher = synth_teacher(&meta, &mut rng);
    let (x, y) = synth_batch(&meta, &mut rng, &teacher);
    let params: Vec<Tensor> =
        meta.init_params(5).into_iter().map(|t| t.cast(DType::F64)).collect();
    let args = vec![params_value(&params), Value::Tensor(x), Value::Tensor(y)];

    let e = Engine::from_source(MLP_SOURCE).unwrap();
    let fused = e
        .trace("mlp_loss")
        .unwrap()
        .value_and_grad()
        .optimize(PassSet::Standard)
        .compile()
        .unwrap();
    let plain = e
        .trace("mlp_loss")
        .unwrap()
        .value_and_grad()
        .optimize(no_fusion())
        .compile()
        .unwrap();
    let _ = fused.vm.take_stats();
    let a = fused.call(args.clone()).unwrap();
    let stats = fused.vm.take_stats();
    let b = plain.call(args).unwrap();
    assert!(a.structural_eq(&b), "MLP value_and_grad diverged under fusion");
    assert!(stats.fused_ops >= 1, "MLP adjoint produced no fused dispatches: {stats:?}");
    assert!(stats.allocs_saved > 0, "{stats:?}");
}

#[test]
fn i64_binary_ops_are_exact_above_2_pow_53() {
    // Regression: the old f64 round-trip lost the low bits of large i64s.
    let big = (1i64 << 60) + 1;
    let a = Tensor::from_i64_shaped(vec![big, -big, 7], vec![3]).unwrap();
    let b = Tensor::from_i64_shaped(vec![1, 2, 3], vec![3]).unwrap();

    let s = ops::add(&a, &b).unwrap();
    assert_eq!(s.dtype(), DType::I64, "i64 + i64 must stay i64");
    match s.buffer() {
        myia::tensor::Buffer::I64(v) => {
            assert_eq!(v, &vec![big + 1, -big + 2, 10], "exact large-i64 addition");
        }
        other => panic!("expected i64 buffer, got {}", other.dtype()),
    }

    let m = ops::mul(&a, &b).unwrap();
    match m.buffer() {
        myia::tensor::Buffer::I64(v) => {
            assert_eq!(v, &vec![big, -2 * big, 21], "exact large-i64 multiplication");
        }
        other => panic!("expected i64 buffer, got {}", other.dtype()),
    }

    // Through the whole VM pipeline too.
    let e = Engine::from_source("def f(a, b):\n    return a * b + a\n").unwrap();
    let f = e.trace("f").unwrap().compile().unwrap();
    let out = f
        .call(vec![
            Value::Tensor(Tensor::from_i64_shaped(vec![big], vec![1]).unwrap()),
            Value::Tensor(Tensor::from_i64_shaped(vec![1], vec![1]).unwrap()),
        ])
        .unwrap();
    let t = out.as_tensor().unwrap().clone();
    assert_eq!(t.dtype(), DType::I64);
    match t.buffer() {
        myia::tensor::Buffer::I64(v) => assert_eq!(v, &vec![2 * big]),
        other => panic!("expected i64 buffer, got {}", other.dtype()),
    }
}

#[test]
fn aliasing_same_tensor_both_operands() {
    // x * x with one register read twice: only the final read may be moved,
    // so the multiply sees both operands intact.
    let e = Engine::from_source("def f(x):\n    return x * x\n").unwrap();
    let f = e.trace("f").unwrap().compile().unwrap();
    let keep = Tensor::from_f64(&[1.0, -2.0, 3.0]);
    let out = f.call(vec![Value::Tensor(keep.clone())]).unwrap();
    assert_eq!(out.as_tensor().unwrap().as_f64_vec(), vec![1.0, 4.0, 9.0]);
    // The caller's reference is untouched.
    assert_eq!(keep.as_f64_vec(), vec![1.0, -2.0, 3.0]);
}

#[test]
fn shared_operand_is_never_mutated_in_place() {
    let orig = Tensor::from_f64(&[1.0, 2.0, 3.0]);
    let other = Tensor::from_f64(&[10.0, 10.0, 10.0]);
    // `orig.clone()` shares the buffer (refcount 2): the owned kernel must
    // allocate instead of writing through.
    let out = ops::binary_num_owned(orig.clone(), other.clone(), ops::NumOp::Add).unwrap();
    assert_eq!(out.as_f64_vec(), vec![11.0, 12.0, 13.0]);
    assert_eq!(orig.as_f64_vec(), vec![1.0, 2.0, 3.0], "shared operand mutated!");

    // A uniquely-owned operand IS reused.
    let before = buffer_reuse_count();
    let unique = Tensor::from_f64(&[5.0, 6.0, 7.0]);
    let out = ops::binary_num_owned(unique, other, ops::NumOp::Add).unwrap();
    assert_eq!(out.as_f64_vec(), vec![15.0, 16.0, 17.0]);
    assert!(buffer_reuse_count() > before, "unique operand was not reused");
}

#[test]
fn eight_threads_on_one_executable_match_sequential_oracle() {
    // Reuse decisions depend on runtime refcounts; under concurrency they
    // must never let one call's in-place write leak into another's data.
    let gsrc = format!("{CHAIN_SRC}\ndef loss(x):\n    return item(sum(f(x)))\n");
    let e = Engine::from_source(&gsrc).unwrap();
    let f = e.trace("loss").unwrap().grad().optimize(PassSet::Standard).compile().unwrap();
    let inputs: Vec<Tensor> = (0..8)
        .map(|i| {
            let vals: Vec<f64> = (0..64).map(|j| ((i * 64 + j) as f64).sin()).collect();
            Tensor::from_f64(&vals)
        })
        .collect();
    let oracle: Vec<Value> = inputs
        .iter()
        .map(|t| f.call(vec![Value::Tensor(t.clone())]).unwrap())
        .collect();

    let results: Vec<Vec<Value>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let f = &f;
                let inputs = &inputs;
                s.spawn(move || {
                    (0..20)
                        .flat_map(|_| {
                            inputs
                                .iter()
                                .map(|t| f.call(vec![Value::Tensor(t.clone())]).unwrap())
                                .collect::<Vec<_>>()
                        })
                        .collect::<Vec<Value>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for thread_out in &results {
        for (k, v) in thread_out.iter().enumerate() {
            let want = &oracle[k % oracle.len()];
            assert!(v.structural_eq(want), "thread result diverged: {v} vs {want}");
        }
    }
    // And the inputs the threads shared were never mutated.
    for (i, t) in inputs.iter().enumerate() {
        assert_eq!(t.as_f64_vec()[0], ((i * 64) as f64).sin());
    }
}

#[test]
fn fingerprints_without_fusion_are_stable() {
    // The fusion pass rides inside `opt=standard` without renaming it, so
    // every pre-existing pipeline spec keeps its fingerprint (and cached
    // artifacts stay valid). The ablation arm parses and is distinct.
    let std_pipe = Pipeline::parse("grad,opt=standard,vm").unwrap();
    assert_eq!(std_pipe.spec(), "grad,opt=standard,vm");
    let ablated = Pipeline::parse("grad,opt=no-fusion,vm").unwrap();
    assert_eq!(ablated.spec(), "grad,opt=no-fusion,vm");
    assert_ne!(std_pipe.fingerprint(), ablated.fingerprint());
    assert!(PassSet::parse("no-fusion").is_ok());
    assert!(Pipeline::parse("opt=no-fusio,vm").is_err());
}

#[test]
fn fusion_composes_with_vmap() {
    // grad-then-vmap per-example gradients with fusion on/off agree bitwise.
    let src = "def f(x):\n    return item(sum(exp(neg(x)) * x + 0.5))\n";
    let e = Engine::from_source(src).unwrap();
    let fused = e
        .trace("f")
        .unwrap()
        .grad()
        .vmap()
        .optimize(PassSet::Standard)
        .compile()
        .unwrap();
    let plain = e
        .trace("f")
        .unwrap()
        .grad()
        .vmap()
        .optimize(no_fusion())
        .compile()
        .unwrap();
    let x = Tensor::from_f64_shaped((0..12).map(|i| 0.1 * i as f64).collect(), vec![4, 3]).unwrap();
    let a = fused.call(vec![Value::Tensor(x.clone())]).unwrap();
    let b = plain.call(vec![Value::Tensor(x)]).unwrap();
    assert!(a.structural_eq(&b), "vmapped adjoint diverged under fusion: {a} vs {b}");
}

//! The transform/pipeline public API: composition, cache behavior under the
//! (entry, pipeline fingerprint, signature) key, and programmatic grads.
//!
//! The headline property: `trace("f").grad().grad().compile()` is a second
//! derivative with no `grad(grad(...))` string anywhere in user source, and
//! pipelines that canonicalize identically — however they were built —
//! share one compiled artifact.

use myia::prelude::*;
use myia::tensor::DType;
use myia::types::AType;
use std::sync::Arc;

const CUBIC: &str = "def f(x):\n    return x ** 3.0\n";

#[test]
fn second_order_grad_matches_analytic() {
    // f = x³ → f'' = 6x, via reverse-over-reverse as a composed pipeline.
    let s = Engine::from_source(CUBIC).unwrap();
    let d2 = s.trace("f").unwrap().grad().grad().compile().unwrap();
    for x in [0.5, 2.0, -1.25] {
        let got = d2.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap();
        assert!((got - 6.0 * x).abs() < 1e-9, "x={x}: got {got}, want {}", 6.0 * x);
    }
    // Programmatic grads are counted separately from source-level macros.
    assert_eq!(d2.metrics.grad_transforms, 2);
    assert_eq!(d2.metrics.macros_expanded, 0);
}

#[test]
fn third_order_grad_matches_analytic() {
    // f = x³ → f''' = 6.
    let s = Engine::from_source(CUBIC).unwrap();
    let d3 = s.trace("f").unwrap().grad().grad().grad().compile().unwrap();
    let got = d3.call(vec![Value::F64(1.7)]).unwrap().as_f64().unwrap();
    assert!((got - 6.0).abs() < 1e-6, "{got}");
}

#[test]
fn same_pipeline_built_three_ways_hits_cache() {
    let s = Engine::from_source(CUBIC).unwrap();
    // 1. the Function chain: two .grad() calls merge to grad^2.
    let a = s.trace("f").unwrap().grad().grad().compile().unwrap();
    // 2. an explicit builder pipeline with Grad { order: 2 }.
    let p = Pipeline::builder()
        .grad_spec(2, 0)
        .optimize(PassSet::Standard)
        .lower(Backend::Vm)
        .build()
        .unwrap();
    let b = s.compile_pipeline("f", &p).unwrap();
    // 3. the parsed CLI spec.
    let q = Pipeline::parse("grad^2,opt=standard,vm").unwrap();
    let c = s.compile_pipeline("f", &q).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "builder pipeline must hit the chain's cache entry");
    assert!(Arc::ptr_eq(&a, &c), "parsed pipeline must hit the chain's cache entry");
    assert_eq!(a.metrics.pipeline, "grad^2,opt=standard,vm");
}

#[test]
fn differing_pass_sets_and_grad_orders_miss() {
    let s = Engine::from_source(CUBIC).unwrap();
    let full = s.trace("f").unwrap().grad().compile().unwrap();
    let ablated = s
        .trace("f")
        .unwrap()
        .grad()
        .optimize(PassSet::Without("inline".to_string()))
        .compile()
        .unwrap();
    let unopt = s.trace("f").unwrap().grad().optimize(PassSet::None).compile().unwrap();
    let second = s.trace("f").unwrap().grad().grad().compile().unwrap();
    assert!(!Arc::ptr_eq(&full, &ablated));
    assert!(!Arc::ptr_eq(&full, &unopt));
    assert!(!Arc::ptr_eq(&full, &second));
    // All first-order variants still agree on the derivative.
    for f in [&full, &ablated, &unopt] {
        let got = f.call(vec![Value::F64(2.0)]).unwrap().as_f64().unwrap();
        assert!((got - 12.0).abs() < 1e-12, "{got}");
    }
}

#[test]
fn grad_wrt_selects_the_parameter() {
    // f(x, y) = x·y² : ∂f/∂x = y², ∂f/∂y = 2xy. The CLI `grad` subcommand
    // rides on exactly this path, so multi-argument entry points work.
    let src = "def f(x, y):\n    return x * y * y\n";
    let s = Engine::from_source(src).unwrap();
    let dx = s.trace("f").unwrap().grad_wrt(0).compile().unwrap();
    let dy = s.trace("f").unwrap().grad_wrt(1).compile().unwrap();
    let args = vec![Value::F64(3.0), Value::F64(2.0)];
    let gx = dx.call(args.clone()).unwrap().as_f64().unwrap();
    let gy = dy.call(args).unwrap().as_f64().unwrap();
    assert!((gx - 4.0).abs() < 1e-12, "∂f/∂x: {gx}");
    assert!((gy - 12.0).abs() < 1e-12, "∂f/∂y: {gy}");
    // Different wrt = different pipeline = different cache entry.
    assert!(!Arc::ptr_eq(&dx, &dy));
}

#[test]
fn grad_wrt_out_of_range_is_reported() {
    let s = Engine::from_source(CUBIC).unwrap();
    let e = s.trace("f").unwrap().grad_wrt(3).compile().unwrap_err();
    assert!(format!("{e}").contains("out of range"), "{e}");
}

#[test]
fn value_and_grad_transform_shares_the_forward_pass() {
    let s = Engine::from_source(CUBIC).unwrap();
    let vg = s.trace("f").unwrap().value_and_grad().compile().unwrap();
    match vg.call(vec![Value::F64(2.0)]).unwrap() {
        Value::Tuple(items) => {
            assert!((items[0].as_f64().unwrap() - 8.0).abs() < 1e-12);
            assert!((items[1].as_f64().unwrap() - 12.0).abs() < 1e-12);
        }
        other => panic!("expected (value, grad), got {other}"),
    }
}

#[test]
fn argument_signature_joins_the_cache_key() {
    let s = Engine::from_source("def f(x):\n    return x + 1.0\n").unwrap();
    let generic = s.trace("f").unwrap().compile().unwrap();
    let spec = s.trace("f").unwrap().specialize(vec![AType::F64]).compile().unwrap();
    let spec_again = s.trace("f").unwrap().specialize(vec![AType::F64]).compile().unwrap();
    // Same pipeline, different signature → different artifact; repeating
    // the signature hits the specialized entry.
    assert!(!Arc::ptr_eq(&generic, &spec));
    assert!(Arc::ptr_eq(&spec, &spec_again));
    assert_eq!(spec.signature.as_deref(), Some(&[AType::F64][..]));
    assert!(spec.ret_type.is_some(), "specialized compile infers a return type");
    assert!(generic.ret_type.is_none());
}

#[test]
fn specialization_checks_shapes_eagerly() {
    // Incompatible matmul shapes are rejected at compile time (§4.2), not
    // at the first call.
    let src = "def g(a, b):\n    return matmul(a, b)\n";
    let s = Engine::from_source(src).unwrap();
    let bad = vec![
        AType::Tensor { dtype: DType::F64, shape: vec![Some(2), Some(3)] },
        AType::Tensor { dtype: DType::F64, shape: vec![Some(4), Some(5)] },
    ];
    let e = s.trace("g").unwrap().specialize(bad).compile().unwrap_err();
    assert!(format!("{e}").contains("mismatch"), "{e}");
}

#[test]
fn function_pipeline_reports_canonical_spec() {
    let s = Engine::from_source(CUBIC).unwrap();
    let f = s.trace("f").unwrap().grad().jit(Backend::Xla);
    let p = f.pipeline().unwrap();
    assert_eq!(p.spec(), "grad,opt=standard,xla");
    assert_eq!(p.backend(), Backend::Xla);
}

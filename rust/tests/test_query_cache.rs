//! Integration tests for query-based incremental compilation and the
//! persistent disk artifact cache.
//!
//! The contract under test (ISSUE 8):
//! * editing one of N functions re-runs only the queries that depend on it
//!   (asserted through query telemetry, not timing);
//! * an artifact round-trips through the disk cache across two `Engine`
//!   instances with bit-identical execution;
//! * truncated / corrupted / schema-bumped cache files degrade to a cold
//!   compile without surfacing an error.

use myia::coordinator::Engine;
use myia::opt::PassSet;
use myia::types::AType;
use myia::vm::Value;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const SRC_V1: &str = "\
def leaf_a(x):
    return x * x + 1.0

def leaf_b(x):
    return sin(x) * x

def mid(x):
    return leaf_a(x) + leaf_b(x)

def top_a(x):
    return leaf_a(x) * 2.0

def top_b(x):
    return leaf_b(x) - 1.0

def top_mid(x):
    return mid(x) + 0.5
";

/// V1 with exactly one function edited: `leaf_b` now uses `cos`.
const SRC_V2: &str = "\
def leaf_a(x):
    return x * x + 1.0

def leaf_b(x):
    return cos(x) * x

def mid(x):
    return leaf_a(x) + leaf_b(x)

def top_a(x):
    return leaf_a(x) * 2.0

def top_b(x):
    return leaf_b(x) - 1.0

def top_mid(x):
    return mid(x) + 0.5
";

fn call_f64(f: &myia::coordinator::Executable, x: f64) -> f64 {
    f.call(vec![Value::F64(x)]).unwrap().as_f64().unwrap()
}

/// Fresh per-test cache directory (removed at both ends so a crashed
/// earlier run can't poison this one).
fn temp_cache_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("myia-qc-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cache_files(dir: &Path) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension() == Some(std::ffi::OsStr::new("myic")))
        .collect();
    files.sort();
    files
}

#[test]
fn incremental_edit_reruns_only_dependents() {
    let mut e = Engine::from_source(SRC_V1).unwrap();
    let entries = ["top_a", "top_b", "top_mid"];
    let mut first: Vec<Arc<myia::coordinator::Executable>> = Vec::new();
    for name in entries {
        first.push(e.trace(name).unwrap().compile().unwrap());
    }
    let q0 = e.query_stats();
    let c0 = e.cache_stats();

    e.update_source(SRC_V2).unwrap();
    let mut second = Vec::new();
    for name in entries {
        second.push(e.trace(name).unwrap().compile().unwrap());
    }
    let q1 = e.query_stats();
    let c1 = e.cache_stats();

    // `top_a` never touches `leaf_b`: its deep fingerprint is unchanged, so
    // the hot tier serves the original artifact untouched.
    assert!(Arc::ptr_eq(&first[0], &second[0]), "top_a must keep its artifact");
    assert_eq!(c1.hits - c0.hits, 1, "exactly one hot-tier hit: {c0:?} -> {c1:?}");
    assert_eq!(c1.misses - c0.misses, 2, "exactly two recompiles: {c0:?} -> {c1:?}");

    // The reparse is one new revision; of the six functions only `leaf_b`
    // refingerprints red, the other five revalidate green.
    assert_eq!(q1.parse.executed - q0.parse.executed, 1);
    assert_eq!(q1.graph_fingerprint.executed - q0.graph_fingerprint.executed, 1, "{q1:?}");
    assert_eq!(q1.graph_fingerprint.green - q0.graph_fingerprint.green, 5, "{q1:?}");

    // Only the two dependent entry points walk the compile DAG again:
    // one expand, one optimize, one codegen query each.
    assert_eq!(q1.ad_expand.executed - q0.ad_expand.executed, 2, "{q1:?}");
    assert_eq!(q1.optimize.executed - q0.optimize.executed, 2, "{q1:?}");
    assert_eq!(q1.codegen.executed - q0.codegen.executed, 2, "{q1:?}");

    // The recompiled artifacts compute the edited program.
    let x = 0.8;
    let want_top_b = x.cos() * x - 1.0;
    let want_top_mid = (x * x + 1.0) + x.cos() * x + 0.5;
    assert!((call_f64(&second[1], x) - want_top_b).abs() < 1e-12);
    assert!((call_f64(&second[2], x) - want_top_mid).abs() < 1e-12);

    // The recorded dependency edges name the transitive callee closure.
    let deps = e.query_dependencies("top_mid").unwrap();
    for needed in ["leaf_a", "leaf_b", "mid", "top_mid"] {
        assert!(deps.iter().any(|d| d == needed), "{needed} missing from {deps:?}");
    }
}

#[test]
fn second_signature_reuses_ir_stages() {
    let e = Engine::from_source(SRC_V1).unwrap();
    let generic = e.trace("top_a").unwrap().compile().unwrap();
    let q0 = e.query_stats();

    // Same entry, same pipeline, new signature: the expand and optimize
    // queries answer from memo; only typecheck and codegen run.
    let specialized =
        e.trace("top_a").unwrap().specialize(vec![AType::F64]).compile().unwrap();
    let q1 = e.query_stats();
    assert_eq!(q1.ad_expand.executed, q0.ad_expand.executed, "{q1:?}");
    assert_eq!(q1.optimize.executed, q0.optimize.executed, "{q1:?}");
    assert!(q1.ad_expand.memo > q0.ad_expand.memo, "{q1:?}");
    assert!(q1.optimize.memo > q0.optimize.memo, "{q1:?}");
    assert_eq!(q1.typecheck.executed - q0.typecheck.executed, 1, "{q1:?}");
    assert_eq!(q1.codegen.executed - q0.codegen.executed, 1, "{q1:?}");

    assert!(!Arc::ptr_eq(&generic, &specialized));
    assert_eq!(specialized.ret_type(), Some(&AType::F64));
    let x = 1.3;
    assert_eq!(call_f64(&generic, x).to_bits(), call_f64(&specialized, x).to_bits());
}

#[test]
fn disk_round_trip_across_engines_is_bit_identical() {
    let dir = temp_cache_dir("roundtrip");
    let points = [0.3, -1.1, 2.4];

    // Cold oracle: compile in one engine, record exact output bits.
    let (cold_grad, cold_raw, nodes_opt) = {
        let e = Engine::from_source(SRC_V1).unwrap().with_cache_dir(&dir).unwrap();
        let g = e.trace("top_mid").unwrap().grad().compile().unwrap();
        // A PassSet::None adjoint keeps the env/Key plumbing in the IR —
        // the serializer must round-trip those constants too.
        let raw = e
            .trace("top_b")
            .unwrap()
            .grad()
            .optimize(PassSet::None)
            .compile()
            .unwrap();
        let stats = e.cache_stats();
        assert!(stats.disk_writes >= 2, "{stats:?}");
        assert_eq!(stats.disk_hits, 0, "{stats:?}");
        let gs: Vec<u64> = points.iter().map(|&x| call_f64(&g, x).to_bits()).collect();
        let rs: Vec<u64> = points.iter().map(|&x| call_f64(&raw, x).to_bits()).collect();
        (gs, rs, g.metrics.nodes_after_optimize)
    };
    assert!(!cache_files(&dir).is_empty());

    // A second engine instance (stand-in for a fresh process with the same
    // MYIA_CACHE_DIR) must start warm and execute bit-identically.
    let e = Engine::from_source(SRC_V1).unwrap().with_cache_dir(&dir).unwrap();
    let g = e.trace("top_mid").unwrap().grad().compile().unwrap();
    let raw =
        e.trace("top_b").unwrap().grad().optimize(PassSet::None).compile().unwrap();
    let stats = e.cache_stats();
    assert!(stats.disk_hits >= 2, "{stats:?}");
    assert_eq!(stats.misses, 0, "warm engine must not compile: {stats:?}");
    assert_eq!(g.metrics.nodes_after_optimize, nodes_opt);
    for (i, &x) in points.iter().enumerate() {
        assert_eq!(call_f64(&g, x).to_bits(), cold_grad[i], "x={x}");
        assert_eq!(call_f64(&raw, x).to_bits(), cold_raw[i], "x={x}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_and_stale_cache_files_degrade_to_cold_compile() {
    let dir = temp_cache_dir("corrupt");
    let oracle = {
        let e = Engine::from_source(SRC_V1).unwrap().with_cache_dir(&dir).unwrap();
        let f = e.trace("top_mid").unwrap().grad().compile().unwrap();
        call_f64(&f, 0.6)
    };

    // Truncate every artifact to half its length: the loader must detect,
    // quarantine, and recompile cold — never error.
    for p in cache_files(&dir) {
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
    }
    {
        let e = Engine::from_source(SRC_V1).unwrap().with_cache_dir(&dir).unwrap();
        let f = e.trace("top_mid").unwrap().grad().compile().unwrap();
        assert_eq!(call_f64(&f, 0.6).to_bits(), oracle.to_bits());
        let stats = e.cache_stats();
        assert!(stats.disk_invalid >= 1, "{stats:?}");
        assert_eq!(stats.disk_hits, 0, "{stats:?}");
        assert_eq!(stats.misses, 1, "{stats:?}");
    }

    // Flip a payload byte under an intact header: checksum catches it.
    for p in cache_files(&dir) {
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
    }
    {
        let e = Engine::from_source(SRC_V1).unwrap().with_cache_dir(&dir).unwrap();
        let f = e.trace("top_mid").unwrap().grad().compile().unwrap();
        assert_eq!(call_f64(&f, 0.6).to_bits(), oracle.to_bits());
        assert!(e.cache_stats().disk_invalid >= 1, "{:?}", e.cache_stats());
    }

    // A schema bump (bytes 4..8 of the header) must read as stale, not
    // crash — future-versioned files are rejected the same way.
    for p in cache_files(&dir) {
        let mut bytes = std::fs::read(&p).unwrap();
        let bumped = myia::runtime::diskcache::SCHEMA_VERSION + 1;
        bytes[4..8].copy_from_slice(&bumped.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
    }
    {
        let e = Engine::from_source(SRC_V1).unwrap().with_cache_dir(&dir).unwrap();
        let f = e.trace("top_mid").unwrap().grad().compile().unwrap();
        assert_eq!(call_f64(&f, 0.6).to_bits(), oracle.to_bits());
        assert!(e.cache_stats().disk_invalid >= 1, "{:?}", e.cache_stats());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn source_edit_changes_the_disk_key() {
    let dir = temp_cache_dir("editkey");
    {
        let e = Engine::from_source(SRC_V1).unwrap().with_cache_dir(&dir).unwrap();
        let f = e.trace("top_b").unwrap().compile().unwrap();
        let x = 0.9;
        assert!((call_f64(&f, x) - (x.sin() * x - 1.0)).abs() < 1e-12);
    }
    // Same entry name, same pipeline, edited source: the deep module
    // fingerprint differs, so the V1 artifact must not be served.
    let e = Engine::from_source(SRC_V2).unwrap().with_cache_dir(&dir).unwrap();
    let f = e.trace("top_b").unwrap().compile().unwrap();
    let x = 0.9;
    assert!((call_f64(&f, x) - (x.cos() * x - 1.0)).abs() < 1e-12);
    let stats = e.cache_stats();
    assert_eq!(stats.disk_hits, 0, "stale artifact served: {stats:?}");
    assert!(stats.disk_misses >= 1, "{stats:?}");
    assert_eq!(stats.misses, 1, "{stats:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

//! Property tests over randomly generated programs, using the in-crate
//! `ptest` substrate (now with program shrinking: a failing case is
//! greedily minimized and the reduced source is reported alongside the
//! seed, and written under `target/ptest/` for CI artifact upload):
//!
//! 1. optimization preserves semantics (random expression, random input);
//! 2. ST gradients agree with central finite differences;
//! 3. forward and reverse mode agree with each other;
//! 4. the compile pipeline never panics on generated programs.

use myia::coordinator::Engine;
use myia::opt::PassSet;
use myia::ptest::{self, Expr};
use myia::vm::Value;

fn eval(src: &str, entry: &str, optimize: bool, x: f64) -> Result<f64, String> {
    let s = Engine::from_source(src).map_err(|e| e.to_string())?;
    let passes = if optimize { PassSet::Standard } else { PassSet::None };
    let f = s
        .trace(entry)
        .map_err(|e| e.to_string())?
        .optimize(passes)
        .compile()
        .map_err(|e| e.to_string())?;
    match f.call(vec![Value::F64(x)]).map_err(|e| e.to_string())? {
        Value::F64(v) => Ok(v),
        Value::Tensor(t) => t.item().map_err(|e| e.to_string()),
        other => Err(format!("non-numeric result {other}")),
    }
}

#[test]
fn optimization_preserves_semantics() {
    ptest::check_exprs(ptest::Config { cases: 40, seed: 0xA11CE }, 3, |expr, rng| {
        let src = format!("def f(x):\n    return {expr}\n");
        let x = ptest::gen_value(rng);
        let a = eval(&src, "f", true, x)?;
        let b = eval(&src, "f", false, x)?;
        ptest::close(a, b, 1e-12, &format!("opt vs unopt on {expr}"))
    });
}

#[test]
fn gradients_match_finite_differences() {
    ptest::check_exprs(ptest::Config { cases: 30, seed: 0xBEE }, 3, |expr, rng| {
        let src = format!(
            "def f(x):\n    return {expr}\n\ndef main(x):\n    return grad(f)(x)\n"
        );
        let x = ptest::gen_value(rng);
        let g = eval(&src, "main", true, x)?;
        let eps = 1e-6;
        let fp = eval(&src, "f", true, x + eps)?;
        let fm = eval(&src, "f", true, x - eps)?;
        let fd = (fp - fm) / (2.0 * eps);
        ptest::close(g, fd, 1e-4, &format!("grad vs fd on {expr} at {x}"))
    });
}

#[test]
fn forward_agrees_with_reverse() {
    ptest::check_exprs(ptest::Config { cases: 25, seed: 0xF0D }, 3, |expr, rng| {
        let src_r = format!(
            "def f(x):\n    return {expr}\n\ndef main(x):\n    return grad(f)(x)\n"
        );
        let src_f = format!(
            "def f(x):\n    return {expr}\n\ndef main(x):\n    return jfwd(f)(x, 1.0)[1]\n"
        );
        let x = ptest::gen_value(rng);
        let r = eval(&src_r, "main", true, x)?;
        let f = eval(&src_f, "main", true, x)?;
        ptest::close(r, f, 1e-10, &format!("fwd vs rev on {expr}"))
    });
}

#[test]
fn pipeline_never_panics_on_generated_control_flow() {
    ptest::check_exprs(ptest::Config { cases: 20, seed: 4242 }, 2, |expr, rng| {
        let n = 1 + rng.below(4);
        let src = format!(
            "def f(x):\n    acc = 0.0\n    for i in range({n}):\n        acc = acc + {expr}\n    \
             if acc > 0.0:\n        return acc\n    return -acc\n\ndef main(x):\n    return grad(f)(x)\n"
        );
        let x = ptest::gen_value(rng);
        // Must not panic; result must be finite.
        let g = eval(&src, "main", true, x)?;
        if g.is_finite() {
            Ok(())
        } else {
            Err(format!("non-finite gradient {g} for {src}"))
        }
    });
}

/// The shrinker itself, driven through the real compiler: plant a property
/// that rejects `sigmoid` and check the minimized program is the sigmoid
/// leaf — i.e. shrinking works against real compile-and-run properties.
#[test]
fn shrinking_finds_minimal_compiler_case() {
    let prop = |e: &Expr| -> Result<(), String> {
        let src = format!("def f(x):\n    return {e}\n");
        let v = eval(&src, "f", true, 0.3)?;
        if !v.is_finite() {
            return Err("non-finite".into());
        }
        // Artificial defect: claim programs containing sigmoid are broken.
        if src.contains("sigmoid") {
            return Err("sigmoid rejected".into());
        }
        Ok(())
    };
    let bad = Expr::Bin(
        "*",
        Box::new(Expr::Un("tanh", Box::new(Expr::Un("sigmoid", Box::new(Expr::X))))),
        Box::new(Expr::Bin("+", Box::new(Expr::X), Box::new(Expr::Const(1.5)))),
    );
    assert!(prop(&bad).is_err());
    let min = ptest::shrink_expr(&bad, |e| prop(e).is_err());
    assert_eq!(min.to_src(), "sigmoid(x)");
    assert!(min.size() < bad.size());
}

//! Cross-module integration: full pipeline (source → grad → optimize → VM),
//! the three AD implementations agreeing with each other, and the Figure 1
//! node-count collapse.

use myia::baselines::tape;
use myia::coordinator::Engine;
use myia::opt::PassSet;
use myia::vm::Value;

fn f64v(v: &Value) -> f64 {
    match v {
        Value::Tensor(t) => t.item().unwrap(),
        other => other.as_f64().unwrap_or_else(|| panic!("expected number, got {other}")),
    }
}

#[test]
fn figure1_collapse_to_handwritten_form() {
    // Paper Figure 1: grad(x ** 3). After optimization the program must be
    // within a small constant of the hand-written 3·x² (times cotangent).
    let src = "\
def f(x):
    return x ** 3.0

def main(x):
    return grad(f)(x)

def handwritten(x):
    return 3.0 * x ** 2.0
";
    let s = Engine::from_source(src).unwrap();
    let auto = s.trace("main").unwrap().compile().unwrap();
    let hand = s.trace("handwritten").unwrap().compile().unwrap();

    for x in [-1.5, 0.0, 2.0, 3.7] {
        let a = f64v(&auto.call(vec![Value::F64(x)]).unwrap());
        let h = f64v(&hand.call(vec![Value::F64(x)]).unwrap());
        assert!((a - h).abs() < 1e-12, "x={x}: {a} vs {h}");
    }

    // Node-count collapse: the optimized adjoint is a small multiple of the
    // hand-written program, and a large shrink from the expanded form.
    let auto_nodes = auto.metrics.nodes_after_optimize;
    let hand_nodes = hand.metrics.nodes_after_optimize;
    assert!(
        auto_nodes <= hand_nodes + 8,
        "optimized adjoint has {auto_nodes} nodes vs hand-written {hand_nodes}"
    );
    assert!(auto.metrics.nodes_after_expand > 4 * auto_nodes,
        "expand {} vs optimized {}", auto.metrics.nodes_after_expand, auto_nodes);
}

#[test]
fn st_and_oo_and_forward_agree() {
    // f(x) = tanh(x)·x + exp(x) : three independent AD implementations.
    let x0 = 0.8f64;
    let want = {
        // analytic: tanh + x·(1−tanh²) + eˣ
        let t = x0.tanh();
        t + x0 * (1.0 - t * t) + x0.exp()
    };

    // 1. ST (the paper's contribution).
    let src = "\
def f(x):
    return tanh(x) * x + exp(x)

def main(x):
    return grad(f)(x)
";
    let s = Engine::from_source(src).unwrap();
    let st = f64v(&s.trace("main").unwrap().compile().unwrap().call(vec![Value::F64(x0)]).unwrap());
    assert!((st - want).abs() < 1e-12, "ST {st} vs analytic {want}");

    // 2. OO tape baseline (§2.1.1).
    let tp = tape::Tape::new();
    let x = tape::scalar(&tp, x0);
    let y = x.tanh().mul(&x).add(&x.exp());
    let grads = y.backward().unwrap();
    let oo = y.grad_of(&grads, &x).as_f64().unwrap();
    assert!((oo - want).abs() < 1e-12, "OO {oo} vs analytic {want}");

    // 3. Forward mode.
    let src_f = "\
def f(x):
    return tanh(x) * x + exp(x)

def main(x, dx):
    return jfwd(f)(x, dx)
";
    let s2 = Engine::from_source(src_f).unwrap();
    let out = s2
        .trace("main")
        .unwrap()
        .compile()
        .unwrap()
        .call(vec![Value::F64(x0), Value::F64(1.0)])
        .unwrap();
    let fwd = match &out {
        Value::Tuple(items) => f64v(&items[1]),
        other => panic!("{other}"),
    };
    assert!((fwd - want).abs() < 1e-12, "fwd {fwd} vs analytic {want}");
}

#[test]
fn gradient_matches_finite_differences_on_composite_program() {
    let src = "\
def model(x):
    acc = 0.0
    i = 0
    while i < 3:
        acc = acc + sin(x * (1.0 + acc))
        i = i + 1
    return acc

def main(x):
    return grad(model)(x)
";
    let s = Engine::from_source(src).unwrap();
    let g = s.trace("main").unwrap().compile().unwrap();
    let f = s.trace("model").unwrap().compile().unwrap();
    for x0 in [0.2, 0.9, -0.7] {
        let eps = 1e-6;
        let fp = f64v(&f.call(vec![Value::F64(x0 + eps)]).unwrap());
        let fm = f64v(&f.call(vec![Value::F64(x0 - eps)]).unwrap());
        let fd = (fp - fm) / (2.0 * eps);
        let gr = f64v(&g.call(vec![Value::F64(x0)]).unwrap());
        assert!((fd - gr).abs() < 1e-5, "x={x0}: fd {fd} vs grad {gr}");
    }
}

#[test]
fn recursion_differentiates_where_dataflow_cannot() {
    // E4's core contrast: this program is inexpressible in the dataflow
    // baseline (no function calls, §2.2), and differentiates fine here.
    let src = "\
def tree_value(depth, x):
    if depth == 0:
        return x
    left = tree_value(depth - 1, x * 0.9)
    right = tree_value(depth - 1, x * 1.1)
    return tanh(left) + tanh(right)

def loss(x):
    return tree_value(4, x)

def main(x):
    return grad(loss)(x)
";
    let s = Engine::from_source(src).unwrap();
    let g = s.trace("main").unwrap().compile().unwrap();
    let f = s.trace("loss").unwrap().compile().unwrap();
    let x0 = 0.3;
    let eps = 1e-6;
    let fd = (f64v(&f.call(vec![Value::F64(x0 + eps)]).unwrap())
        - f64v(&f.call(vec![Value::F64(x0 - eps)]).unwrap()))
        / (2.0 * eps);
    let gr = f64v(&g.call(vec![Value::F64(x0)]).unwrap());
    assert!((fd - gr).abs() < 1e-5, "fd {fd} vs grad {gr}");

    // And the dataflow baseline rejects the same shape of program.
    let mut df = myia::baselines::DataflowGraph::new();
    assert!(df.call("tree_value", &[]).is_err());
}

#[test]
fn optimized_and_unoptimized_agree_on_tensor_grads() {
    let src = "\
def loss(w, x):
    h = tanh(matmul(w, x))
    return item(sum(h * h))

def main(w, x):
    return grad(loss)(w, x)
";
    let w = Value::Tensor(
        myia::tensor::Tensor::from_f64_shaped(vec![0.1, -0.2, 0.3, 0.4], vec![2, 2]).unwrap(),
    );
    let x = Value::Tensor(
        myia::tensor::Tensor::from_f64_shaped(vec![1.0, 0.5, -0.5, 0.2], vec![2, 2]).unwrap(),
    );
    let s1 = Engine::from_source(src).unwrap();
    let opt = s1.trace("main").unwrap().compile().unwrap();
    let s2 = Engine::from_source(src).unwrap();
    let unopt = s2.trace("main").unwrap().optimize(PassSet::None).compile().unwrap();
    let a = opt.call(vec![w.clone(), x.clone()]).unwrap();
    let b = unopt.call(vec![w, x]).unwrap();
    let (ta, tb) = (a.as_tensor().unwrap(), b.as_tensor().unwrap());
    assert!(ta.allclose(tb, 1e-12), "{ta:?} vs {tb:?}");
}

#[test]
fn eager_shape_errors_before_execution() {
    let src = "def f(a, b):\n    return matmul(a, b)\n";
    let s = Engine::from_source(src).unwrap();
    let a = Value::Tensor(myia::tensor::Tensor::zeros(myia::tensor::DType::F64, &[2, 3]));
    let b = Value::Tensor(myia::tensor::Tensor::zeros(myia::tensor::DType::F64, &[4, 5]));
    let e = s.check_call("f", &[a, b]).unwrap_err();
    assert!(format!("{e}").contains("mismatch"), "{e}");
}

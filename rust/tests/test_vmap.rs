//! The loop-vs-vmap oracle: for random generated programs and batch sizes,
//! `vmap(f)` applied to stacked inputs must agree with stacking `f` over a
//! plain loop — a second independent oracle (besides finite differences)
//! for every random program. Plus `vmap(grad(f))` spot-checks against
//! per-example finite differences, both composition orders, and the
//! pipeline-spec surface.

use myia::coordinator::Engine;
use myia::ptest::{self, Expr};
use myia::tensor::Tensor;
use myia::transform::Pipeline;
use myia::vm::Value;

fn as_scalar(v: &Value) -> Result<f64, String> {
    match v {
        Value::F64(x) => Ok(*x),
        Value::Tensor(t) => t.item().map_err(|e| e.to_string()),
        other => Err(format!("non-numeric result {other}")),
    }
}

fn as_vec(v: &Value) -> Result<Vec<f64>, String> {
    match v {
        Value::Tensor(t) => Ok(t.as_f64_vec()),
        other => Err(format!("expected stacked tensor result, got {other}")),
    }
}

#[test]
fn vmap_agrees_with_stacked_loop_on_random_programs() {
    ptest::check_exprs(ptest::Config { cases: 30, seed: 0x7A9 }, 3, |expr, rng| {
        let src = format!("def f(x):\n    return {expr}\n");
        let batch = 1 + rng.below(5);
        let xs: Vec<f64> = (0..batch).map(|_| ptest::gen_value(rng)).collect();
        let s = Engine::from_source(&src).map_err(|e| e.to_string())?;
        let vf = s
            .trace("f")
            .map_err(|e| e.to_string())?
            .vmap()
            .compile()
            .map_err(|e| e.to_string())?;
        let stacked = vf
            .call(vec![Value::Tensor(Tensor::from_f64(&xs))])
            .map_err(|e| e.to_string())?;
        let got = as_vec(&stacked)?;
        if got.len() != xs.len() {
            return Err(format!("vmap returned {} results for {} inputs", got.len(), xs.len()));
        }
        let f = s.trace("f").map_err(|e| e.to_string())?.compile().map_err(|e| e.to_string())?;
        for (i, &x) in xs.iter().enumerate() {
            let want = as_scalar(&f.call(vec![Value::F64(x)]).map_err(|e| e.to_string())?)?;
            ptest::close(got[i], want, 1e-10, &format!("vmap vs loop on {expr} at example {i}"))?;
        }
        Ok(())
    });
}

#[test]
fn vmap_of_grad_matches_per_example_finite_differences() {
    ptest::check_exprs(ptest::Config { cases: 15, seed: 0x5EED }, 3, |expr, rng| {
        let src = format!("def f(x):\n    return {expr}\n");
        let xs: Vec<f64> = (0..4).map(|_| ptest::gen_value(rng)).collect();
        let s = Engine::from_source(&src).map_err(|e| e.to_string())?;
        // grad then vmap: per-example derivatives, one compiled artifact.
        let pg = s
            .trace("f")
            .map_err(|e| e.to_string())?
            .grad()
            .vmap()
            .compile()
            .map_err(|e| e.to_string())?;
        let grads = as_vec(
            &pg.call(vec![Value::Tensor(Tensor::from_f64(&xs))])
                .map_err(|e| e.to_string())?,
        )?;
        let f = s.trace("f").map_err(|e| e.to_string())?.compile().map_err(|e| e.to_string())?;
        let eps = 1e-6;
        for (i, &x) in xs.iter().enumerate() {
            let fp = as_scalar(&f.call(vec![Value::F64(x + eps)]).map_err(|e| e.to_string())?)?;
            let fm = as_scalar(&f.call(vec![Value::F64(x - eps)]).map_err(|e| e.to_string())?)?;
            let fd = (fp - fm) / (2.0 * eps);
            ptest::close(
                grads[i],
                fd,
                1e-4,
                &format!("vmap(grad) vs fd on {expr} at example {i}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn grad_of_vmap_gives_per_example_derivatives_for_elementwise_programs() {
    // The other composition order: differentiating the batched program.
    // The scalar seed broadcasts over the stacked output, and because the
    // program is elementwise across examples the cross terms vanish — the
    // gradient is again the per-example derivative vector.
    let src = "def f(x):\n    return x * x + sin(x)\n";
    let s = Engine::from_source(src).unwrap();
    let g = s.trace("f").unwrap().vmap().grad().compile().unwrap();
    let xs = [0.3, -1.2, 2.0];
    let out = g.call(vec![Value::Tensor(Tensor::from_f64(&xs))]).unwrap();
    let got = as_vec(&out).unwrap();
    for (i, &x) in xs.iter().enumerate() {
        let want = 2.0 * x + x.cos();
        assert!((got[i] - want).abs() < 1e-10, "example {i}: {} vs {want}", got[i]);
    }
}

#[test]
fn vmap_grad_linear_model_per_sample_grads() {
    // Per-sample gradients of a vector-parameter model: sum_to_like toward
    // the shared weights must keep the example axis (sum_to_tail), not
    // accumulate over it.
    let src = "\
def loss(w, x, y):
    d = item(sum(x * w)) - y
    return d * d
";
    let s = Engine::from_source(src).unwrap();
    let per_sample = s
        .trace("loss")
        .unwrap()
        .grad()
        .vmap_axes(vec![None, Some(0), Some(0)])
        .compile()
        .unwrap();
    let w = Tensor::from_f64(&[0.5, -1.0, 2.0]);
    let xs = Tensor::from_f64_shaped(
        vec![1.0, 0.0, 1.0, 0.0, 2.0, -1.0, 1.0, 1.0, 1.0, -2.0, 0.5, 0.0],
        vec![4, 3],
    )
    .unwrap();
    let ys = Tensor::from_f64(&[1.0, -2.0, 0.5, 3.0]);
    let out = per_sample
        .call(vec![
            Value::Tensor(w.clone()),
            Value::Tensor(xs.clone()),
            Value::Tensor(ys.clone()),
        ])
        .unwrap();
    let got = out.as_tensor().unwrap();
    assert_eq!(got.shape(), &[4, 3]);
    // Oracle: the same Grad pipeline looped over examples.
    let g1 = s.trace("loss").unwrap().grad().compile().unwrap();
    for e in 0..4 {
        let xe: Vec<f64> = xs.as_f64_vec()[e * 3..(e + 1) * 3].to_vec();
        let ye = ys.as_f64_vec()[e];
        let ge = g1
            .call(vec![
                Value::Tensor(w.clone()),
                Value::Tensor(Tensor::from_f64(&xe)),
                Value::F64(ye),
            ])
            .unwrap();
        let want = ge.as_tensor().unwrap().as_f64_vec();
        let row = &got.as_f64_vec()[e * 3..(e + 1) * 3];
        for (a, b) in row.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-10, "example {e}: {row:?} vs {want:?}");
        }
    }
}

#[test]
fn grad_through_vmapped_adjoint_matches_finite_differences() {
    // Differentiate THROUGH the vmapped adjoint: the `grad,vmap@n.0.0`
    // pipeline emits `sum_to_tail` (the batched sum_to_like toward the
    // shared weights), so a further `grad` stage needs sum_to_tail's
    // backpropagator — formerly "honestly unsupported", now implemented via
    // `broadcast_tail`. Oracle: central finite differences of the summed
    // per-sample-gradient output.
    let src = "\
def loss(w, x, y):
    d = item(sum(x * w)) - y
    return d * d
";
    let s = Engine::from_source(src).unwrap();
    let per_sample = s
        .trace("loss")
        .unwrap()
        .grad()
        .vmap_axes(vec![None, Some(0), Some(0)])
        .compile()
        .unwrap();
    let through = s
        .trace("loss")
        .unwrap()
        .grad()
        .vmap_axes(vec![None, Some(0), Some(0)])
        .grad()
        .compile()
        .unwrap();
    assert_eq!(through.metrics.pipeline, "grad,vmap@n.0.0,grad,opt=standard,vm");

    let w = [0.5, -1.0, 2.0];
    let xs = Tensor::from_f64_shaped(
        vec![1.0, 0.0, 1.0, 0.0, 2.0, -1.0, 1.0, 1.0, 1.0, -2.0, 0.5, 0.0],
        vec![4, 3],
    )
    .unwrap();
    let ys = Tensor::from_f64(&[1.0, -2.0, 0.5, 3.0]);

    // S(w) = Σ over all entries of the stacked per-sample gradients; the
    // scalar grad seed broadcasts over the [B, 3] output, so the second
    // grad computes ∇S.
    let total = |wv: &[f64]| -> f64 {
        per_sample
            .call(vec![
                Value::Tensor(Tensor::from_f64(wv)),
                Value::Tensor(xs.clone()),
                Value::Tensor(ys.clone()),
            ])
            .unwrap()
            .as_tensor()
            .unwrap()
            .as_f64_vec()
            .iter()
            .sum()
    };
    let got = through
        .call(vec![
            Value::Tensor(Tensor::from_f64(&w)),
            Value::Tensor(xs.clone()),
            Value::Tensor(ys.clone()),
        ])
        .unwrap();
    let got = got.as_tensor().unwrap().as_f64_vec();
    assert_eq!(got.len(), 3);
    let eps = 1e-5;
    for k in 0..3 {
        let mut up = w.to_vec();
        up[k] += eps;
        let mut down = w.to_vec();
        down[k] -= eps;
        let fd = (total(&up) - total(&down)) / (2.0 * eps);
        assert!(
            (got[k] - fd).abs() < 1e-6,
            "component {k}: grad-through-vmap {} vs finite difference {fd}",
            got[k]
        );
    }
}

#[test]
fn vmap_pipeline_spec_end_to_end() {
    // The CLI surface: a parsed `--pipeline` spec with a vmap stage.
    let src = "def f(x, s):\n    return tanh(x) * s\n";
    let s = Engine::from_source(src).unwrap();
    let p = Pipeline::parse("vmap@0.n,opt=standard,vm").unwrap();
    assert_eq!(p.spec(), "vmap@0.n,opt=standard,vm");
    let f = s.compile_pipeline("f", &p).unwrap();
    let xs = [0.1, 0.7, -0.4];
    let out = f
        .call(vec![Value::Tensor(Tensor::from_f64(&xs)), Value::F64(2.0)])
        .unwrap();
    let got = as_vec(&out).unwrap();
    for (i, &x) in xs.iter().enumerate() {
        assert!((got[i] - 2.0 * x.tanh()).abs() < 1e-12);
    }
    // Cache key: the vmapped artifact is distinct from the plain one.
    let plain = s.trace("f").unwrap().compile().unwrap();
    assert_ne!(plain.metrics.pipeline, f.metrics.pipeline);
}

#[test]
fn vmap_through_loops_matches_stacked_loop() {
    // Control flow independent of the mapped input threads the batch axis
    // through the lowered thunks/recursion untouched.
    let src = "\
def f(x):
    acc = x
    i = 0
    while i < 4:
        acc = acc * x + 0.25
        i = i + 1
    return acc
";
    let s = Engine::from_source(src).unwrap();
    let vf = s.trace("f").unwrap().vmap().compile().unwrap();
    let xs = [0.9, -0.3, 1.1, 0.0];
    let got = as_vec(&vf.call(vec![Value::Tensor(Tensor::from_f64(&xs))]).unwrap()).unwrap();
    let f = s.trace("f").unwrap().compile().unwrap();
    for (i, &x) in xs.iter().enumerate() {
        let want = as_scalar(&f.call(vec![Value::F64(x)]).unwrap()).unwrap();
        assert!((got[i] - want).abs() < 1e-12, "example {i}");
    }
}

#[test]
fn vmap_rejects_data_dependent_branches_with_clear_error() {
    let src = "def f(x):\n    return x if x > 0.0 else -x\n";
    let s = Engine::from_source(src).unwrap();
    let e = s.trace("f").unwrap().vmap().compile().unwrap_err();
    assert!(format!("{e}").contains("data-dependent"), "{e}");
}
